//! Parallel experiment runner: fan independent scenario runs across
//! cores.
//!
//! The companion paper (Doyle et al., arXiv:1604.04804) sweeps
//! estimator × policy × workload grids; every cell is an independent
//! deterministic simulation, so the whole sweep is embarrassingly
//! parallel. [`run_many`] is a rayon-style scoped worker pool over a
//! shared atomic work index (the offline vendor set has no rayon; the
//! pool is `std::thread::scope` + `AtomicUsize`, and swapping the body
//! of `run_many` for `rayon::par_iter` is a three-line change if the
//! vendor set ever gains it).
//!
//! **Determinism**: each [`RunSpec`] carries a self-contained
//! [`Scenario`] (own config/seed, own suite), and every simulation is a
//! pure function of it. Results are returned in spec order regardless of
//! which worker ran which spec or in what order they finished, so a
//! sweep is bit-identical across thread counts — `tests/determinism.rs`
//! pins sequential == 2 threads == 8 threads, including a
//! spot-reclamation scenario (revocations come from the seeded market).
//!
//! Grid cells run with estimator-trace recording **off**: the traces are
//! never read by sweep reporting and are the largest per-tick allocation
//! source (rust/BENCHMARKS.md).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::config::Config;
use crate::coordinator::PolicyKind;
use crate::db::TaskStatus;
use crate::estimation::{BankCache, EstimatorKind};
use crate::metrics::RunMetrics;
use crate::platform::{
    ArrivalProcess, FaultSpec, Platform, RunOpts, Scenario, ScenarioBuilder, StreamSpec,
};
use crate::sim::SimTime;
use crate::workload::{paper_suite, App, WorkloadSpec};

/// One cell of an experiment grid: a fully self-contained scenario plus
/// its display label.
#[derive(Debug, Clone)]
pub struct RunSpec {
    pub label: String,
    pub scenario: Scenario,
}

impl RunSpec {
    pub fn new(label: impl Into<String>, scenario: Scenario) -> Self {
        RunSpec { label: label.into(), scenario }
    }

    /// Compatibility constructor over the `RunOpts` shim (fixed-interval
    /// arrivals, fault-free spot fleet).
    pub fn from_opts(
        label: impl Into<String>,
        cfg: Config,
        suite: Vec<WorkloadSpec>,
        opts: RunOpts,
    ) -> Self {
        RunSpec::new(label, Scenario::from_opts(cfg, suite, opts))
    }

    /// Execute this cell (pure in its inputs) through the process-wide
    /// bank cache.
    pub fn execute(&self) -> anyhow::Result<RunMetrics> {
        self.scenario.run()
    }

    /// Execute this cell resolving its estimator bank through an
    /// explicit shared [`BankCache`] — the N cells of a grid that share
    /// a (W, K, estimator, params) bank shape pay backend selection
    /// once. Cached and uncached execution are bit-identical
    /// (`estimation::cache` determinism pin).
    pub fn execute_with_cache(&self, cache: &BankCache) -> anyhow::Result<RunMetrics> {
        self.scenario.run_with_cache(cache)
    }

    /// Total tasks this cell simulates (throughput accounting).
    pub fn n_tasks(&self) -> usize {
        self.scenario.n_tasks()
    }
}

/// Default worker count: one per core, at least 1.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Evaluate `f(0..n)` on a pool of `threads` scoped workers pulling
/// indices from a shared atomic counter (work-stealing-lite: the
/// counter is the one queue). Results land in pre-sized **per-index
/// slots**, so collection never serializes workers on a shared lock
/// (the pre-PR-4 version funneled every result through one
/// `Mutex<Vec>`): each slot's mutex is touched by exactly the one
/// worker that claimed its index, making every lock acquisition
/// uncontended, and index order holds by construction — no post-sort.
/// `threads <= 1` runs inline with no pool.
pub fn run_many<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|s| {
            s.into_inner()
                .unwrap()
                .expect("every claimed index writes its slot before the scope joins")
        })
        .collect()
}

/// Run every spec of a grid, `threads`-wide, through the process-wide
/// bank cache; results in spec order.
pub fn run_specs(specs: &[RunSpec], threads: usize) -> anyhow::Result<Vec<RunMetrics>> {
    run_specs_with_cache(specs, threads, BankCache::global())
}

/// Run every spec of a grid, `threads`-wide, sharing one explicit
/// [`BankCache`] across all cells; results in spec order.
pub fn run_specs_with_cache(
    specs: &[RunSpec],
    threads: usize,
    cache: &BankCache,
) -> anyhow::Result<Vec<RunMetrics>> {
    run_many(specs.len(), threads, |i| specs[i].execute_with_cache(cache))
        .into_iter()
        .collect()
}

/// Shared base for the §V-C grids: 5-minute monitoring, paper suite,
/// traces off (sweeps never read them).
fn grid_cell(base: &Config, suite: &[WorkloadSpec]) -> ScenarioBuilder {
    ScenarioBuilder::new(base.clone())
        .workloads(suite.to_vec())
        .horizon(16 * 3600)
        .record_traces(false)
}

/// The default cost-experiment grid (§V-C / Table III): the 5 scaling
/// methods × 2 fixed TTCs over the paper suite, 5-minute monitoring.
pub fn cost_grid(cfg: &Config) -> Vec<RunSpec> {
    let mut base = cfg.clone();
    base.control.monitor_interval_s = 300;
    let suite = paper_suite(base.seed);
    let mut specs = vec![];
    for &ttc in &[super::cost::TTC_LONG_S, super::cost::TTC_SHORT_S] {
        let as_kind = if ttc == super::cost::TTC_LONG_S {
            PolicyKind::AmazonAs1
        } else {
            PolicyKind::AmazonAs10
        };
        for (name, policy, fixed_ttc) in [
            ("aimd", PolicyKind::Aimd, Some(ttc)),
            ("reactive", PolicyKind::Reactive, Some(ttc)),
            ("mwa", PolicyKind::Mwa, Some(ttc)),
            ("lr", PolicyKind::Lr, Some(ttc)),
            ("amazon-as", as_kind, None),
        ] {
            specs.push(RunSpec::new(
                format!("cost/{name}/ttc{ttc}"),
                grid_cell(&base, &suite)
                    .policy(policy)
                    .estimator(EstimatorKind::Kalman)
                    .fixed_ttc(fixed_ttc)
                    .build(),
            ));
        }
    }
    specs
}

/// Estimator-shootout grid (Table II axis): each estimator drives the
/// same suite.
pub fn estimator_grid(cfg: &Config) -> Vec<RunSpec> {
    let mut base = cfg.clone();
    base.control.monitor_interval_s = 300;
    let suite = paper_suite(base.seed);
    EstimatorKind::ALL
        .iter()
        .map(|&estimator| {
            RunSpec::new(
                format!("estimator/{}", estimator.name()),
                grid_cell(&base, &suite)
                    .estimator(estimator)
                    .fixed_ttc(Some(super::cost::TTC_LONG_S))
                    .build(),
            )
        })
        .collect()
}

/// Seed-sweep / ablation grid: `n` independent replicas with
/// deterministic per-run seeds derived from the master seed, each with
/// its own suite realization.
pub fn seed_grid(cfg: &Config, n: usize) -> Vec<RunSpec> {
    (0..n)
        .map(|i| {
            let mut c = cfg.clone();
            c.control.monitor_interval_s = 300;
            c.seed = cfg.seed.wrapping_add(i as u64);
            let suite = paper_suite(c.seed);
            RunSpec::new(
                format!("seed/{}", c.seed),
                grid_cell(&c, &suite)
                    .fixed_ttc(Some(super::cost::TTC_LONG_S))
                    .build(),
            )
        })
        .collect()
}

/// Streaming million-task grid (`dithen sweep stream`): suites are
/// *generated at arrival instants* (no up-front materialization) and
/// terminal shards are retired, so resident memory tracks the arrival
/// window — not the task total — and a million-task run fits in CI.
/// `smoke` keeps only the 100k-task cell (`dithen sweep stream
/// --smoke`, the CI gate); the full grid adds the 1M-task cell the
/// PR-8 bench report measures.
pub fn stream_grid(cfg: &Config, smoke: bool) -> Vec<RunSpec> {
    let mut base = cfg.clone();
    base.use_xla = false; // streaming needs the growable native bank
    let cell = |n_workloads: usize, label: &str| {
        RunSpec::new(
            format!("stream/{label}"),
            ScenarioBuilder::new(base.clone())
                .stream(StreamSpec {
                    n_workloads,
                    tasks_per_workload: 100,
                    app: App::ImRotate,
                })
                .retire_shards(true)
                .fixed_ttc(Some(3600))
                .arrivals(ArrivalProcess::FixedInterval { interval_s: 60 })
                // every slot admits (last arrival + ample drain time):
                // the horizon must clear the stream or the twin caveat
                // in rust/BENCHMARKS.md applies
                .horizon(60 * n_workloads as SimTime + 8 * 3600)
                .record_traces(false)
                .build(),
        )
    };
    let mut g = vec![cell(1_000, "100k")];
    if !smoke {
        g.push(cell(10_000, "1m"));
    }
    g
}

/// Controller bake-off grid (`dithen sweep policies`, PR-9): the
/// proposed AIMD/PID/MPC controllers and the reactive baseline, each
/// under the Kalman estimator and the arxiv-1604.04804-style
/// last-observation ("reactive") estimator, on the spot-reclamation
/// scenario — the regime where forecast quality actually moves the
/// cost-vs-deadline-violations trade. `smoke` swaps the paper suite for
/// a 3-workload CI-sized suite (the `sweep policies --smoke` CI step).
pub fn policy_grid(cfg: &Config, smoke: bool) -> Vec<RunSpec> {
    let mut base = cfg.clone();
    base.control.monitor_interval_s = 300;
    let suite: Vec<WorkloadSpec> = if smoke {
        let rng = crate::util::rng::Rng::new(base.seed);
        (0..3).map(|w| WorkloadSpec::generate(w, App::FaceDetection, 40, None, &rng)).collect()
    } else {
        paper_suite(base.seed)
    };
    let mut specs = vec![];
    for (pname, policy) in [
        ("aimd", PolicyKind::Aimd),
        ("pid", PolicyKind::Pid),
        ("mpc", PolicyKind::Mpc),
        ("reactive", PolicyKind::Reactive),
    ] {
        for (ename, estimator) in
            [("kalman", EstimatorKind::Kalman), ("reactive", EstimatorKind::Reactive)]
        {
            specs.push(RunSpec::new(
                format!("policy/{pname}+{ename}"),
                grid_cell(&base, &suite)
                    .policy(policy)
                    .estimator(estimator)
                    .fixed_ttc(Some(super::cost::TTC_LONG_S))
                    .fault(FaultSpec::SpotReclamation { bid: 0.0082 })
                    .build(),
            ));
        }
    }
    specs
}

/// Serialize the policy grid's results as a `dithen-bench-report/v1`
/// payload whose `policy_pareto` block carries one point per
/// (policy, estimator) cell: total cost, TTC compliance, the deadline
/// violation rate (`1 − compliance`), and whether the cell *dominates*
/// the reactive-policy + reactive-estimator baseline cell (≤ on both
/// axes, < on at least one). `rust/BENCHMARKS.md` documents the format.
pub fn policy_pareto_json(specs: &[RunSpec], results: &[RunMetrics]) -> String {
    let baseline = specs
        .iter()
        .position(|s| s.label == "policy/reactive+reactive")
        .map(|i| &results[i]);
    let rows = specs
        .iter()
        .zip(results)
        .map(|(s, m)| {
            let violations = 1.0 - m.ttc_compliance();
            let dominates = baseline.is_some_and(|b| {
                let bv = 1.0 - b.ttc_compliance();
                m.total_cost <= b.total_cost
                    && violations <= bv
                    && (m.total_cost < b.total_cost || violations < bv)
            });
            format!(
                "{{\"label\": \"{}\", \"policy\": \"{}\", \"estimator\": \"{}\", \
                 \"cost\": {:.4}, \"ttc_compliance\": {:.4}, \
                 \"deadline_violations\": {:.4}, \"finished_at\": {}, \
                 \"dominates_reactive_baseline\": {}}}",
                s.label,
                s.scenario.policy.name(),
                s.scenario.estimator.name(),
                m.total_cost,
                m.ttc_compliance(),
                violations,
                m.finished_at,
                dominates,
            )
        })
        .collect::<Vec<_>>()
        .join(",\n    ");
    format!(
        "{{\n  \"schema\": \"dithen-bench-report/v1\",\n  \"grid\": \"policies\",\n\
         \x20 \"policy_pareto\": [\n    {rows}\n  ]\n}}\n"
    )
}

/// Every grid `dithen sweep` accepts — the single source of truth the
/// CLI usage text and the `unknown sweep` error render from.
pub const SWEEP_GRIDS: &[&str] =
    &["cost", "estimators", "seeds", "fleet", "smoke", "sparse", "stream", "policies"];

/// Run a named grid and render a summary table (the `dithen sweep`
/// subcommand). `batched` routes execution through the lockstep
/// batched executor (`dithen sweep --batched`; bit-identical results —
/// see [`super::batched`]); `smoke` trims grids that honor it (today:
/// `stream`) to their CI-sized cells.
pub fn run_sweep(
    name: &str,
    cfg: &Config,
    threads: usize,
    batched: bool,
    smoke: bool,
) -> anyhow::Result<String> {
    let specs = match name {
        "cost" => cost_grid(cfg),
        "estimators" => estimator_grid(cfg),
        "seeds" => seed_grid(cfg, 8),
        "fleet" => super::heterogeneous::grid(cfg, 6, 100, 12 * 3600),
        "smoke" => super::bench_report::smoke_grid(cfg),
        "sparse" => super::bench_report::sparse_grid(cfg),
        "stream" => stream_grid(cfg, smoke),
        "policies" => policy_grid(cfg, smoke),
        other => {
            anyhow::bail!("unknown sweep '{other}' (use {})", SWEEP_GRIDS.join(" | "))
        }
    };
    if batched && specs.iter().any(|s| s.scenario.stream.is_some()) {
        anyhow::bail!(
            "sweep '{name}' streams its suites; the lockstep batched executor needs \
             materialized cells (drop --batched)"
        );
    }
    let cache = BankCache::global();
    let cache_before = cache.stats();
    let t0 = std::time::Instant::now();
    let results = if batched {
        super::batched::run_specs_batched(&specs, threads, cache)?
    } else {
        run_specs(&specs, threads)?
    };
    let wall = t0.elapsed().as_secs_f64();
    let cache_after = cache.stats();
    let mut table = crate::util::table::Table::new(vec![
        "run",
        "cost ($)",
        "max inst",
        "TTC (%)",
        "finished",
    ]);
    let mut tasks = 0usize;
    for (spec, m) in specs.iter().zip(&results) {
        tasks += spec.n_tasks();
        table.row(vec![
            spec.label.clone(),
            format!("{:.3}", m.total_cost),
            format!("{}", m.max_instances),
            format!("{:.0}", 100.0 * m.ttc_compliance()),
            crate::util::table::fmt_hm(m.finished_at as f64),
        ]);
    }
    let summary = format!(
        "{} runs / {tasks} simulated tasks in {wall:.2}s on {threads} threads{} \
         ({:.0} tasks/s) | bank cache: {} cold builds / {} hits\n",
        specs.len(),
        if batched { " [lockstep-batched]" } else { "" },
        tasks as f64 / wall.max(1e-9),
        cache_after.cold_builds - cache_before.cold_builds,
        cache_after.hits - cache_before.hits,
    );
    let mut out = format!("{}{summary}", table.render());
    if name == "policies" {
        let pareto = policy_pareto_json(&specs, &results);
        let path = "out/policy-pareto.json";
        std::fs::create_dir_all("out")?;
        std::fs::write(path, &pareto)?;
        out.push_str(&format!("wrote {path} (cost-vs-violations Pareto per policy)\n"));
    }
    println!("{out}");
    Ok(out)
}

// ----- multi-platform driver over disjoint shard sets (PR-5) -----------

/// Partition a many-workload scenario into `parts` sub-scenarios over
/// **disjoint workload shard sets**: contiguous, balanced workload
/// slices, each re-indexed to arrival slots 0.. within its part (the
/// task DB is sharded per workload — PR-4 — so each part's platform
/// owns a disjoint set of [`crate::db::Shard`]s by construction).
///
/// Semantics: each part is an *independent* platform instance — its own
/// fleet bootstrap, its own controller, its own arrival schedule over
/// its subset. That is exactly the paper's horizontal-scale story (one
/// GCI per tenant slice) and the disjoint-workload regime where the
/// decomposition is faithful; workloads that would have contended for
/// one shared controller in the unsplit run are instead isolated, so a
/// multi-part run is *not* bit-equal to the unsplit platform in
/// general. The degenerate 1-part split **is** the unsplit run and is
/// pinned bit-identical through the whole drive/merge machinery
/// (`tests/determinism.rs`).
pub fn split_scenario(scn: &Scenario, parts: usize) -> Vec<Scenario> {
    let n = scn.specs.len();
    let parts = parts.max(1).min(n.max(1));
    let base = n / parts;
    let extra = n % parts;
    // clone the scenario scaffold (config, fleet, fault, ...) with the
    // specs emptied, so each WorkloadSpec is cloned exactly once into
    // its part — not O(parts * n) throwaway clones
    let mut scaffold = scn.clone();
    scaffold.specs = vec![];
    let mut subs = Vec::with_capacity(parts);
    let mut lo = 0usize;
    for p in 0..parts {
        let len = base + usize::from(p < extra);
        let mut sub = scaffold.clone();
        sub.specs = scn.specs[lo..lo + len].to_vec();
        for (j, s) in sub.specs.iter_mut().enumerate() {
            s.id = j;
        }
        subs.push(sub);
        lo += len;
    }
    subs
}

/// Sum step functions (sample-and-hold curves): the merged value at any
/// instant is the sum of every part's latest value. Points are emitted
/// at the union of the parts' sample instants; simultaneous updates
/// collapse to one point carrying the final value. `Exact` integer
/// deltas keep the instances curve lossless; f64 curves accumulate in
/// part order (deterministic).
fn merge_step_curves_f64(curves: &[&[(SimTime, f64)]]) -> Vec<(SimTime, f64)> {
    let mut deltas: Vec<(SimTime, f64)> = vec![];
    for c in curves {
        let mut prev = 0.0;
        for &(t, v) in *c {
            deltas.push((t, v - prev));
            prev = v;
        }
    }
    deltas.sort_by_key(|&(t, _)| t); // stable: ties keep part order
    let mut out: Vec<(SimTime, f64)> = Vec::with_capacity(deltas.len());
    let mut acc = 0.0f64;
    for (t, d) in deltas {
        acc += d;
        match out.last_mut() {
            Some(last) if last.0 == t => last.1 = acc,
            _ => out.push((t, acc)),
        }
    }
    out
}

fn merge_step_curves_usize(curves: &[&[(SimTime, usize)]]) -> Vec<(SimTime, usize)> {
    let mut deltas: Vec<(SimTime, i64)> = vec![];
    for c in curves {
        let mut prev = 0i64;
        for &(t, v) in *c {
            deltas.push((t, v as i64 - prev));
            prev = v as i64;
        }
    }
    deltas.sort_by_key(|&(t, _)| t);
    let mut out: Vec<(SimTime, usize)> = Vec::with_capacity(deltas.len());
    let mut acc = 0i64;
    for (t, d) in deltas {
        acc += d;
        match out.last_mut() {
            Some(last) if last.0 == t => last.1 = acc.max(0) as usize,
            _ => out.push((t, acc.max(0) as usize)),
        }
    }
    out
}

/// Deterministically merge per-part [`RunMetrics`] into one aggregate
/// report: costs/counters sum, curves merge as step-function sums,
/// outcomes and traces concatenate in part order with workload indices
/// re-offset to the original scenario's numbering. A single part is
/// returned unchanged (bit-identity for the 1-part pin).
pub fn merge_metrics(parts: Vec<RunMetrics>) -> RunMetrics {
    if parts.len() <= 1 {
        return parts.into_iter().next().unwrap_or_default();
    }
    let mut out = RunMetrics {
        cost_curve: merge_step_curves_f64(
            &parts.iter().map(|p| p.cost_curve.as_slice()).collect::<Vec<_>>(),
        ),
        n_star_curve: merge_step_curves_f64(
            &parts.iter().map(|p| p.n_star_curve.as_slice()).collect::<Vec<_>>(),
        ),
        instances_curve: merge_step_curves_usize(
            &parts.iter().map(|p| p.instances_curve.as_slice()).collect::<Vec<_>>(),
        ),
        ..RunMetrics::default()
    };
    // concurrent max across platforms from the merged step sum; never
    // below the largest single part's own (intra-sample) max
    let curve_max = out.instances_curve.iter().map(|&(_, v)| v).max().unwrap_or(0);
    let part_max = parts.iter().map(|p| p.max_instances).max().unwrap_or(0);
    out.max_instances = curve_max.max(part_max);
    let mut offset = 0usize;
    for p in parts {
        out.total_cost += p.total_cost;
        out.total_busy_cus += p.total_busy_cus;
        out.finished_at = out.finished_at.max(p.finished_at);
        out.ticks += p.ticks;
        out.ticks_skipped += p.ticks_skipped;
        out.tick_wall_ns += p.tick_wall_ns;
        out.reclamations += p.reclamations;
        out.unfulfilled_requests += p.unfulfilled_requests;
        out.requeued_tasks += p.requeued_tasks;
        out.tasks_completed += p.tasks_completed;
        out.chunk_retries += p.chunk_retries;
        out.speculative_launches += p.speculative_launches;
        out.straggler_instances += p.straggler_instances;
        out.tasks_abandoned += p.tasks_abandoned;
        // peak residency is per-platform (parts never share shards or
        // bank lanes); the aggregate reports the largest single part
        out.peak_live_shards = out.peak_live_shards.max(p.peak_live_shards);
        out.peak_arena_bytes = out.peak_arena_bytes.max(p.peak_arena_bytes);
        if out.reclamations_by_pool.len() < p.reclamations_by_pool.len() {
            out.reclamations_by_pool.resize(p.reclamations_by_pool.len(), 0);
        }
        for (dst, src) in out.reclamations_by_pool.iter_mut().zip(&p.reclamations_by_pool) {
            *dst += *src;
        }
        for ((w, k), trace) in p.traces {
            out.traces.insert((w + offset, k), trace);
        }
        let n_wl = p.outcomes.len();
        out.outcomes.extend(p.outcomes);
        offset += n_wl;
    }
    out
}

/// Run one many-workload scenario as `parts` concurrent platform
/// instances over disjoint workload shard sets and merge their metrics
/// deterministically (spec order; thread count never changes the
/// result). Each part's final task DB is decomposed via
/// [`crate::db::TaskDb::into_shards`] and audited: every terminal task
/// across all shard sets is counted exactly once against the part's
/// reported completions before the merge is trusted.
pub fn run_sharded(
    scn: &Scenario,
    parts: usize,
    threads: usize,
    cache: &BankCache,
) -> anyhow::Result<RunMetrics> {
    let subs = split_scenario(scn, parts);
    type PartRun = anyhow::Result<(RunMetrics, crate::db::TaskDb)>;
    let runs = run_many(subs.len(), threads, |i| -> PartRun {
        let sub = &subs[i];
        sub.validate()?;
        Platform::from_scenario_with_cache(sub.clone(), cache).run_with_db()
    });
    let mut metrics = Vec::with_capacity(subs.len());
    for (run, sub) in runs.into_iter().zip(&subs) {
        let (m, db) = run?;
        // the exactly-once receipt over this part's disjoint shard set
        let terminal: usize = db
            .into_shards()
            .iter()
            .map(|s| s.count_status(TaskStatus::Completed) + s.count_status(TaskStatus::Failed))
            .sum();
        anyhow::ensure!(
            terminal == m.tasks_completed,
            "shard audit: part of {} workloads reports {} completions but its shards hold {} \
             terminal tasks",
            sub.specs.len(),
            m.tasks_completed,
            terminal,
        );
        metrics.push(m);
    }
    Ok(merge_metrics(metrics))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::workload::App;

    fn tiny_specs(n: usize) -> Vec<RunSpec> {
        let rng = Rng::new(5);
        (0..n)
            .map(|i| {
                let mut cfg = Config::paper_defaults();
                cfg.use_xla = false;
                cfg.control.n_min = 4.0;
                cfg.seed = 100 + i as u64;
                RunSpec::from_opts(
                    format!("tiny/{i}"),
                    cfg,
                    vec![WorkloadSpec::generate(0, App::FaceDetection, 15, None, &rng)],
                    RunOpts {
                        fixed_ttc_s: Some(3600),
                        arrival_interval_s: 60,
                        horizon_s: 4 * 3600,
                        ..Default::default()
                    },
                )
            })
            .collect()
    }

    #[test]
    fn run_many_preserves_index_order() {
        let out = run_many(64, 8, |i| i * 3);
        assert_eq!(out, (0..64).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn run_many_handles_edge_sizes() {
        assert!(run_many(0, 4, |i| i).is_empty());
        assert_eq!(run_many(1, 16, |i| i + 7), vec![7]);
        assert_eq!(run_many(5, 1, |i| i), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn parallel_equals_sequential() {
        let specs = tiny_specs(4);
        let seq = run_specs(&specs, 1).unwrap();
        let par = run_specs(&specs, 4).unwrap();
        assert_eq!(seq, par, "thread count changed simulation results");
    }

    /// Cache-contention pin: 8 workers over cells that all share one
    /// (W, K, estimator, params) bank shape — every cell after the
    /// first resolves its bank from the shared cache, concurrently —
    /// must produce exactly the sequential results.
    #[test]
    fn contended_cache_is_thread_count_invariant() {
        let specs = tiny_specs(8); // same suite shape per cell => one variant
        let seq_cache = BankCache::new();
        let seq = run_specs_with_cache(&specs, 1, &seq_cache).unwrap();
        let par_cache = BankCache::new();
        let par = run_specs_with_cache(&specs, 8, &par_cache).unwrap();
        assert_eq!(seq, par, "shared bank cache changed simulation results");
        for (name, cache) in [("sequential", &seq_cache), ("parallel", &par_cache)] {
            let s = cache.stats();
            assert_eq!(s.cold_builds, 1, "{name}: cells share one bank shape");
            assert_eq!(s.hits, specs.len() as u64 - 1, "{name}: all later cells must hit");
        }
    }

    fn assert_labels_unique(specs: &[RunSpec]) {
        let mut labels: Vec<&str> = specs.iter().map(|s| s.label.as_str()).collect();
        labels.sort_unstable();
        let n = labels.len();
        labels.dedup();
        assert_eq!(labels.len(), n, "duplicate sweep labels");
    }

    /// Mirror of `grids_are_well_formed` for the heterogeneous fleet
    /// grid (`dithen sweep fleet`): labels unique, every cell simulates
    /// work, traces stay off in sweeps.
    #[test]
    fn fleet_grid_is_well_formed() {
        let cfg = Config::paper_defaults();
        let g = crate::experiments::heterogeneous::grid(&cfg, 3, 10, 3600);
        assert!(!g.is_empty());
        assert_labels_unique(&g);
        assert!(g.iter().all(|s| s.n_tasks() > 0));
        assert!(g.iter().all(|s| !s.scenario.record_traces));
        // every cell must survive scenario validation (the mixed+bids
        // cell carries the bids reclaim-pools requires)
        for s in &g {
            s.scenario.validate().unwrap_or_else(|e| panic!("{}: {e}", s.label));
        }
    }

    // ----- shard-split driver units ------------------------------------

    fn many_workload_scenario(n_wl: usize) -> Scenario {
        let mut cfg = Config::paper_defaults();
        cfg.use_xla = false;
        cfg.control.n_min = 4.0;
        cfg.seed = 77;
        let rng = Rng::new(cfg.seed);
        let suite: Vec<WorkloadSpec> = (0..n_wl)
            .map(|w| WorkloadSpec::generate(w, App::FaceDetection, 15, None, &rng))
            .collect();
        ScenarioBuilder::new(cfg)
            .workloads(suite)
            .fixed_ttc(Some(3600))
            .arrivals(crate::platform::ArrivalProcess::FixedInterval { interval_s: 60 })
            .horizon(4 * 3600)
            .record_traces(false)
            .build()
    }

    #[test]
    fn split_is_balanced_contiguous_and_reindexed() {
        let scn = many_workload_scenario(5);
        let subs = split_scenario(&scn, 2);
        assert_eq!(subs.len(), 2);
        assert_eq!(subs[0].specs.len(), 3);
        assert_eq!(subs[1].specs.len(), 2);
        // contiguous original order, local ids re-stamped to 0..
        assert_eq!(subs[0].specs[2].name, scn.specs[2].name);
        assert_eq!(subs[1].specs[0].name, scn.specs[3].name);
        for sub in &subs {
            for (j, s) in sub.specs.iter().enumerate() {
                assert_eq!(s.id, j, "workload ids must be local arrival slots");
            }
        }
        // more parts than workloads clamps to one workload per part
        assert_eq!(split_scenario(&scn, 99).len(), 5);
        // a 1-part split is the scenario itself
        let one = split_scenario(&scn, 1);
        assert_eq!(one.len(), 1);
        assert_eq!(one[0].specs.len(), 5);
    }

    #[test]
    fn step_curve_merge_sums_and_holds() {
        let a: Vec<(u64, f64)> = vec![(0, 1.0), (10, 3.0)];
        let b: Vec<(u64, f64)> = vec![(5, 2.0), (10, 4.0), (20, 5.0)];
        let merged = merge_step_curves_f64(&[&a, &b]);
        assert_eq!(merged, vec![(0, 1.0), (5, 3.0), (10, 7.0), (20, 8.0)]);
        let ai: Vec<(u64, usize)> = vec![(0, 2), (10, 1)];
        let bi: Vec<(u64, usize)> = vec![(10, 3), (15, 0)];
        let merged = merge_step_curves_usize(&[&ai, &bi]);
        assert_eq!(merged, vec![(0, 2), (10, 4), (15, 1)]);
    }

    #[test]
    fn merging_one_part_is_identity() {
        let m = many_workload_scenario(2).run().unwrap();
        let merged = merge_metrics(vec![m.clone()]);
        assert_eq!(m, merged);
    }

    #[test]
    fn sharded_run_conserves_tasks_and_sums_cost() {
        let scn = many_workload_scenario(4);
        let cache = BankCache::new();
        let merged = run_sharded(&scn, 2, 2, &cache).unwrap();
        assert_eq!(merged.outcomes.len(), 4);
        assert_eq!(merged.tasks_completed, scn.n_tasks());
        // cost must be the exact sum of the two independent parts
        let subs = split_scenario(&scn, 2);
        let part_cost: f64 =
            subs.iter().map(|s| s.run_with_cache(&cache).unwrap().total_cost).sum();
        assert_eq!(merged.total_cost, part_cost);
        assert!(merged.max_instances >= 1);
    }

    #[test]
    fn grids_are_well_formed() {
        let cfg = Config::paper_defaults();
        let g = cost_grid(&cfg);
        assert_eq!(g.len(), 10); // 5 policies x 2 TTCs
        assert_labels_unique(&g);
        assert!(g.iter().all(|s| s.n_tasks() > 0));
        // sweeps never read traces; recording stays off (perf)
        assert!(g.iter().all(|s| !s.scenario.record_traces));
        // every estimator family rides the Table II axis (PR-9 added
        // EWMA and the reactive last-observation baseline)
        assert_eq!(estimator_grid(&cfg).len(), EstimatorKind::ALL.len());
        assert_eq!(estimator_grid(&cfg).len(), 5);
        assert_labels_unique(&estimator_grid(&cfg));
        let seeds = seed_grid(&cfg, 4);
        assert_eq!(seeds.len(), 4);
        assert_labels_unique(&seeds);
        // per-run seeds are distinct and deterministic
        let s: Vec<u64> = seeds.iter().map(|r| r.scenario.cfg.seed).collect();
        assert_eq!(s, vec![cfg.seed, cfg.seed + 1, cfg.seed + 2, cfg.seed + 3]);
    }

    /// The PR-9 controller bake-off grid: 4 policies × 2 estimators,
    /// every cell on the reclamation scenario, labels unique, traces
    /// off, and both smoke and full variants validate without running.
    #[test]
    fn policy_grid_is_well_formed() {
        let cfg = Config::paper_defaults();
        for smoke in [true, false] {
            let g = policy_grid(&cfg, smoke);
            assert_eq!(g.len(), 8, "4 policies x 2 estimators");
            assert_labels_unique(&g);
            assert!(g.iter().all(|s| s.n_tasks() > 0));
            assert!(g.iter().all(|s| !s.scenario.record_traces));
            for s in &g {
                s.scenario.validate().unwrap_or_else(|e| panic!("{}: {e}", s.label));
                assert_eq!(s.scenario.fault, FaultSpec::SpotReclamation { bid: 0.0082 });
            }
        }
        // the smoke trim shrinks the suite, not the grid shape
        assert!(
            policy_grid(&cfg, true)[0].n_tasks() < policy_grid(&cfg, false)[0].n_tasks(),
            "smoke cells must be CI-sized"
        );
    }

    /// The Pareto payload is valid bench-report v1 JSON and the
    /// dominance flag is `true` exactly for cells at-or-better than the
    /// reactive+reactive baseline on both axes and strictly better on
    /// one.
    #[test]
    fn policy_pareto_json_is_well_formed() {
        let cfg = Config::paper_defaults();
        let specs = policy_grid(&cfg, true);
        let results: Vec<RunMetrics> = specs
            .iter()
            .enumerate()
            .map(|(i, s)| RunMetrics {
                // baseline dearest, aimd+kalman cheapest: compliance is
                // 1.0 across the board (no outcomes), so dominance must
                // key off cost alone here
                total_cost: if s.label == "policy/reactive+reactive" {
                    9.0
                } else {
                    1.0 + i as f64 * 0.1
                },
                finished_at: 3600,
                ..RunMetrics::default()
            })
            .collect();
        let json = policy_pareto_json(&specs, &results);
        let doc = crate::util::json::parse(&json).unwrap();
        assert_eq!(doc.get("schema").and_then(|s| s.as_str()), Some("dithen-bench-report/v1"));
        assert_eq!(doc.get("grid").and_then(|s| s.as_str()), Some("policies"));
        let rows = doc.get("policy_pareto").and_then(|p| p.as_arr()).unwrap();
        assert_eq!(rows.len(), specs.len());
        for (row, spec) in rows.iter().zip(&specs) {
            assert_eq!(row.get("label").and_then(|l| l.as_str()), Some(spec.label.as_str()));
            // every non-baseline cell is strictly cheaper at equal
            // violations; the baseline never dominates itself
            let want = crate::util::json::Json::Bool(spec.label != "policy/reactive+reactive");
            assert_eq!(
                row.get("dominates_reactive_baseline"),
                Some(&want),
                "{}",
                spec.label
            );
        }
    }

    /// The streaming grid is well-formed without running it: the smoke
    /// trim keeps the 100k cell, the full grid adds the 1M cell, every
    /// cell validates (native bank, lazy suite) and counts its tasks
    /// from the stream shape alone.
    #[test]
    fn stream_grid_is_well_formed_and_ci_sized() {
        let cfg = Config::paper_defaults();
        let smoke = stream_grid(&cfg, true);
        assert_eq!(smoke.len(), 1);
        assert_eq!(smoke[0].n_tasks(), 100_000);
        let full = stream_grid(&cfg, false);
        assert_eq!(full.len(), 2);
        assert_labels_unique(&full);
        assert_eq!(full[1].n_tasks(), 1_000_000);
        for s in &full {
            s.scenario.validate().unwrap_or_else(|e| panic!("{}: {e}", s.label));
            assert!(s.scenario.stream.is_some() && s.scenario.retire_shards);
            assert!(s.scenario.specs.is_empty(), "{}: suite must stay lazy", s.label);
            assert!(!s.scenario.record_traces);
            // the horizon admits every slot — the bit-identity twin
            // caveat (rust/BENCHMARKS.md) never applies to shipped grids
            let last = 60 * (s.scenario.stream.as_ref().unwrap().n_workloads as SimTime - 1);
            assert!(s.scenario.horizon_s > last + 3600);
        }
    }
}
