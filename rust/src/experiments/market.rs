//! Fig. 12 / Table V (Appendix A): spot-price behaviour per instance type
//! over a simulated three-month window, and the instance catalogue.

use crate::cloud::market::{Market, CATALOG};
use crate::config::Config;
use crate::util::stats;
use crate::util::table::{ascii_chart, write_csv, Table};

/// Fig. 12: 3-month (11 Apr – 11 Jul 2015 in the paper) hourly spot-price
/// traces for the six catalogue types.
pub fn run_fig12(cfg: &Config) -> anyhow::Result<String> {
    let hours = 24 * 91;
    let market = Market::new(cfg.market.clone(), cfg.seed, hours);
    let mut curves: Vec<(String, Vec<(f64, f64)>)> = vec![];
    for (i, ty) in CATALOG.iter().enumerate() {
        let pts: Vec<(f64, f64)> = market
            .trace(i)
            .hourly
            .iter()
            .enumerate()
            .map(|(h, &p)| (h as f64 / 24.0, p))
            .collect();
        curves.push((ty.name.to_string(), pts));
    }
    let series: Vec<(&str, &[(f64, f64)])> =
        curves.iter().map(|(n, c)| (n.as_str(), c.as_slice())).collect();
    let chart = ascii_chart("fig12 — spot price ($/hr) vs days", &series, 78, 16);
    write_csv(&format!("{}/fig12.csv", super::OUT_DIR), "days", &series)?;
    let mut lines = String::new();
    for (i, ty) in CATALOG.iter().enumerate() {
        let t = market.trace(i);
        lines.push_str(&format!(
            "{:<12} mean={:.4} max={:.4} cv={:.3}\n",
            ty.name,
            t.mean(),
            t.max(),
            stats::std(&t.hourly) / t.mean()
        ));
    }
    let m3max = market.trace(0).max();
    lines.push_str(&format!(
        "m3.medium never exceeds $0.01 over the window: {}\n",
        m3max < 0.01
    ));
    let out = format!("{chart}{lines}");
    println!("{out}");
    Ok(out)
}

/// Table V: the instance catalogue with spot discount percentages.
pub fn run_table5(cfg: &Config) -> anyhow::Result<String> {
    let _ = cfg;
    let mut t = Table::new(vec![
        "instance type",
        "ECUs",
        "CUs",
        "on-demand ($)",
        "spot price ($)",
        "spot reduction (%)",
    ]);
    for ty in CATALOG {
        t.row(vec![
            ty.name.to_string(),
            format!("{}", ty.ecus),
            format!("{}", ty.cus),
            format!("{:.3}", ty.on_demand),
            format!("{:.4}", ty.spot_base),
            format!("{:.0}", 100.0 * (1.0 - ty.spot_base / ty.on_demand)),
        ]);
    }
    let per_cu: Vec<f64> = CATALOG.iter().map(|t| t.on_demand / t.cus as f64).collect();
    let summary = format!(
        "on-demand $/CU/hr: mean {:.4} (std {:.4}) — cost is ~linear in CUs, so many \
         small instances give the finest control granularity (the paper's argument \
         for single-CU m3.medium)\n",
        stats::mean(&per_cu),
        stats::std(&per_cu)
    );
    let out = format!("{}{}", t.render(), summary);
    println!("{out}");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_has_all_types() {
        let out = run_table5(&Config::paper_defaults()).unwrap();
        for ty in CATALOG {
            assert!(out.contains(ty.name));
        }
    }

    #[test]
    fn fig12_reports_m3_stability() {
        let out = run_fig12(&Config::paper_defaults()).unwrap();
        assert!(out.contains("m3.medium never exceeds $0.01 over the window: true"));
    }
}
