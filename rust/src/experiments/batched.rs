//! Lockstep batched sweep executor (PR-5): advance N same-shape sweep
//! cells on one shared tick cadence and execute their estimator-bank
//! steps as **one** padded batch per round instead of one
//! `Bank::step_into` per cell per tick.
//!
//! Dense parameter grids (Doyle et al., arXiv:1604.04804; Li et al.,
//! arXiv:1809.06529) overwhelmingly consist of cells that share one
//! (W, K, params, backend) bank shape — the `cost` grid is 10 cells of
//! a single shape. The per-cell runner (`super::parallel::run_specs`)
//! already parallelizes across cores; this executor additionally
//! vectorizes *across* cells: each round it
//!
//! 1. **pumps** every live cell's event loop to its next monitoring
//!    instant ([`Platform::pump_to_tick`]) and runs the pre-bank tick
//!    phase ([`Platform::tick_gather`]);
//! 2. **gathers** every cell's bank state + tick inputs into one padded
//!    `[N, W*K]` scratch ([`BatchScratch`]);
//! 3. issues **one** [`Bank::step_batch_into`] — a contiguous sweep
//!    over all lanes on the native backend (one padded execution per
//!    lane under a single engine read lock on XLA; see the method docs
//!    for why lanes are not row-concatenated);
//! 4. **scatters** each lane's `StepOutputs` back and runs the
//!    post-bank phase ([`Platform::tick_finish`]).
//!
//! Cells finish (and drop out of the batch) independently; a cell's
//! event history is exactly what a solo [`Scenario::run`] would
//! produce, so batched results are **bit-identical** to the per-cell
//! path and invariant in batch width and thread count
//! (`tests/determinism.rs::batched_sweep_is_bit_identical_to_per_cell`).
//!
//! Grouping: cells are batched only with cells resolving to the *same*
//! cached bank variant (same `Arc` out of the [`BankCache`] — same
//! shape, params, estimator and backend). Mixed grids form one batch
//! group per variant; a cell sharing its variant with nobody runs as a
//! width-1 batch through the same code path.

use std::sync::Arc;

use crate::estimation::{BankCache, BankVariant, BatchScratch};
use crate::metrics::RunMetrics;
use crate::platform::Platform;

use super::parallel::{run_many, RunSpec};

/// Run a grid through the lockstep batched executor, `threads`-wide;
/// results in spec order, bit-identical to
/// [`super::parallel::run_specs`]. Each variant group is split into up
/// to `threads` batches so the worker pool has independent work even
/// when the whole grid shares one bank shape.
pub fn run_specs_batched(
    specs: &[RunSpec],
    threads: usize,
    cache: &BankCache,
) -> anyhow::Result<Vec<RunMetrics>> {
    run_specs_batched_opts(specs, threads, None, cache)
}

/// [`run_specs_batched`] with an explicit cap on the lockstep batch
/// width (`max_batch`; `None` = split each variant group evenly across
/// the worker pool). Width {1, 4, N} and any thread count produce the
/// same results — pinned by the determinism suite.
pub fn run_specs_batched_opts(
    specs: &[RunSpec],
    threads: usize,
    max_batch: Option<usize>,
    cache: &BankCache,
) -> anyhow::Result<Vec<RunMetrics>> {
    if specs.is_empty() {
        return Ok(vec![]);
    }
    // group cells by their resolved bank variant: cells share a batch
    // only when the cache hands both the same Arc (same shape, params,
    // estimator, backend preference) — this doubles as the cache
    // warm-up, so platform assembly below always hits
    let variants: Vec<Arc<BankVariant>> =
        specs.iter().map(|s| s.scenario.bank_variant(cache)).collect();
    let mut groups: Vec<(usize, Vec<usize>)> = vec![];
    for (i, v) in variants.iter().enumerate() {
        let key = Arc::as_ptr(v) as usize;
        match groups.iter_mut().find(|(k, _)| *k == key) {
            Some((_, idxs)) => idxs.push(i),
            None => groups.push((key, vec![i])),
        }
    }
    // chunk each group into batches (deterministic in specs/threads/
    // max_batch only — never in worker scheduling)
    let mut batches: Vec<(Arc<BankVariant>, Vec<usize>)> = vec![];
    for (_, idxs) in groups {
        let width = match max_batch {
            Some(b) => b.max(1),
            None if threads > 1 => idxs.len().div_ceil(threads.min(idxs.len())),
            None => idxs.len(),
        };
        for chunk in idxs.chunks(width.max(1)) {
            batches.push((variants[chunk[0]].clone(), chunk.to_vec()));
        }
    }
    let per_batch = run_many(batches.len(), threads, |b| {
        let (variant, idxs) = &batches[b];
        run_batch(specs, idxs, variant, cache)
    });
    let mut results: Vec<Option<RunMetrics>> = (0..specs.len()).map(|_| None).collect();
    for (batch_results, (_, idxs)) in per_batch.into_iter().zip(&batches) {
        for (m, &i) in batch_results?.into_iter().zip(idxs) {
            results[i] = Some(m);
        }
    }
    Ok(results
        .into_iter()
        .map(|m| m.expect("every spec index lands in exactly one batch"))
        .collect())
}

/// Drive one batch of same-variant cells in lockstep to completion;
/// results aligned with `idxs`.
fn run_batch(
    specs: &[RunSpec],
    idxs: &[usize],
    variant: &BankVariant,
    cache: &BankCache,
) -> anyhow::Result<Vec<RunMetrics>> {
    let n = idxs.len();
    // the template bank contributes shape/params/backend to the batch
    // step; per-cell estimator state lives in each platform's own bank
    let template = variant.instantiate();
    let (w, k) = (template.w, template.k);
    let mut platforms: Vec<Option<Platform>> = Vec::with_capacity(n);
    for &i in idxs {
        let scn = &specs[i].scenario;
        scn.validate()?;
        let mut p = Platform::from_scenario_with_cache(scn.clone(), cache);
        p.start();
        platforms.push(Some(p));
    }
    let mut results: Vec<Option<RunMetrics>> = (0..n).map(|_| None).collect();
    let mut batch = BatchScratch::default();
    let mut live: Vec<usize> = (0..n).collect();
    let mut ticking: Vec<usize> = Vec::with_capacity(n);
    while !live.is_empty() {
        // 1. pump every live cell to its next monitoring instant and
        //    run its pre-bank phase; cells whose run ended finalize
        ticking.clear();
        for &c in &live {
            let p = platforms[c].as_mut().expect("live cell holds a platform");
            if p.pump_to_tick()? {
                p.tick_gather();
                ticking.push(c);
            } else {
                let done = platforms[c].take().expect("live cell holds a platform");
                results[c] = Some(done.finalize()?);
            }
        }
        if ticking.is_empty() {
            break;
        }
        // 2. gather every ticking cell into the padded scratch
        batch.begin(ticking.len(), w, k);
        for &c in &ticking {
            let p = platforms[c].as_ref().expect("ticking cell holds a platform");
            batch.gather(&p.bank, &p.bank_inputs())?;
        }
        // 3. one batch execution for the whole round
        template.step_batch_into(&mut batch)?;
        // 4. scatter outputs back and run each cell's post-bank phase
        for (lane, &c) in ticking.iter().enumerate() {
            let p = platforms[c].as_mut().expect("ticking cell holds a platform");
            batch.scatter(lane, &mut p.bank, &mut p.outs);
            p.tick_finish();
            if p.all_done_at.is_some() {
                let done = platforms[c].take().expect("cell still holds a platform");
                results[c] = Some(done.finalize()?);
            }
        }
        live.clear();
        live.extend(ticking.iter().copied().filter(|&c| platforms[c].is_some()));
    }
    Ok(results
        .into_iter()
        .map(|m| m.expect("every cell either finalizes on pump or after a tick"))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::platform::RunOpts;
    use crate::util::rng::Rng;
    use crate::workload::{App, WorkloadSpec};

    fn tiny_specs(n: usize, n_wl: usize) -> Vec<RunSpec> {
        let rng = Rng::new(5);
        (0..n)
            .map(|i| {
                let mut cfg = Config::paper_defaults();
                cfg.use_xla = false;
                cfg.control.n_min = 4.0;
                cfg.seed = 300 + i as u64;
                let suite: Vec<WorkloadSpec> = (0..n_wl)
                    .map(|w| WorkloadSpec::generate(w, App::FaceDetection, 12, None, &rng))
                    .collect();
                RunSpec::from_opts(
                    format!("batched/{i}"),
                    cfg,
                    suite,
                    RunOpts {
                        fixed_ttc_s: Some(3600),
                        arrival_interval_s: 60,
                        horizon_s: 3 * 3600,
                        record_traces: false,
                        ..Default::default()
                    },
                )
            })
            .collect()
    }

    #[test]
    fn batched_matches_per_cell_on_a_shared_shape_grid() {
        let specs = tiny_specs(5, 1);
        let reference = super::super::parallel::run_specs(&specs, 1).unwrap();
        let cache = BankCache::new();
        let batched = run_specs_batched(&specs, 1, &cache).unwrap();
        assert_eq!(reference, batched, "lockstep batch diverged from per-cell execution");
    }

    #[test]
    fn mixed_shape_grids_form_one_group_per_variant() {
        // 3 one-workload cells + 2 two-workload cells: two variants,
        // so width-unbounded batching must still produce spec-order
        // results identical to the per-cell runner
        let mut specs = tiny_specs(3, 1);
        specs.extend(tiny_specs(2, 2).into_iter().enumerate().map(|(i, mut s)| {
            s.label = format!("batched/two/{i}");
            s
        }));
        let reference = super::super::parallel::run_specs(&specs, 1).unwrap();
        let batched = run_specs_batched(&specs, 2, &BankCache::new()).unwrap();
        assert_eq!(reference, batched);
    }

    #[test]
    fn empty_grid_is_fine() {
        assert!(run_specs_batched(&[], 4, &BankCache::new()).unwrap().is_empty());
    }

    #[test]
    fn invalid_cell_surfaces_as_error() {
        let mut specs = tiny_specs(1, 1);
        specs[0].scenario.fleet = crate::cloud::FleetSpec { pools: vec![] };
        assert!(run_specs_batched(&specs, 1, &BankCache::new()).is_err());
    }
}
