//! Heterogeneous-fleet sweep: homogeneous per-type fleets vs mixed
//! fleets, on cost and deadline violations.
//!
//! Not a paper figure — an extension experiment over the fleet layer:
//! Li et al. (2018) show transcoding cost is dominated by the instance-
//! type mix, and Soltanian et al. (ADS, 2017) scale mixed fleets; the
//! paper's own Table V catalogue spans 1–40 CUs with price volatility
//! growing in CU count (Appendix A). The sweep runs the same workload
//! suite on
//!
//! * one **homogeneous** fleet per catalogue type (each scheduled by the
//!   same AIMD controller, capacity-aware dispatch filling each
//!   instance's CU slots), and
//! * a **mixed** fleet of all those types (greedy cheapest-$/CU fill at
//!   the current spot prices), plus a **mixed+bids** variant where every
//!   pool carries a bid slightly above its Table V base price and the
//!   per-pool market fault model revokes whichever pool spikes
//!   (partial revocation; other pools absorb the requeued work).
//!
//! Reported per cell: total cost, $/task, max concurrent instances, TTC
//! compliance, deadline violations, reclamations (by pool via the run
//! summary), requeued tasks and unfulfilled (above-bid) requests.

use crate::cloud::{FleetSpec, CATALOG};
use crate::config::Config;
use crate::experiments::parallel::{default_threads, run_specs, RunSpec};
use crate::platform::{ArrivalProcess, FaultSpec, ScenarioBuilder};
use crate::util::rng::Rng;
use crate::util::table::Table;
use crate::workload::{App, WorkloadSpec};

/// Catalogue types swept homogeneously (all of Table V).
const TYPES: &[usize] = &[0, 1, 2, 3, 4, 5];

/// Bid margin over the Table V base spot price for the mixed+bids cell:
/// low enough that volatile large types cross it, high enough that the
/// fleet can fulfil requests most of the time.
const BID_MARGIN: f64 = 1.1;

fn mixed_fleet(bids: bool) -> FleetSpec {
    FleetSpec {
        pools: TYPES
            .iter()
            .map(|&t| {
                let bid = CATALOG[t].spot_base * BID_MARGIN;
                crate::cloud::PoolSpec { type_idx: t, bid: bids.then_some(bid) }
            })
            .collect(),
    }
}

/// The sweep grid over a generated suite (`n_wl` workloads of `tasks`
/// tasks each).
pub fn grid(cfg: &Config, n_wl: usize, tasks: usize, horizon_s: u64) -> Vec<RunSpec> {
    let rng = Rng::new(cfg.seed);
    let suite: Vec<WorkloadSpec> = (0..n_wl)
        .map(|i| WorkloadSpec::generate(i, App::FaceDetection, tasks, None, &rng))
        .collect();
    let cell = |fleet: FleetSpec, fault: FaultSpec| {
        ScenarioBuilder::new(cfg.clone())
            .workloads(suite.clone())
            .fixed_ttc(Some(3600))
            .arrivals(ArrivalProcess::FixedInterval { interval_s: 300 })
            .horizon(horizon_s)
            .fleet(fleet)
            .fault(fault)
            .record_traces(false)
            .build()
    };
    let mut specs = vec![];
    for &t in TYPES {
        specs.push(RunSpec::new(
            format!("fleet/homogeneous/{}", CATALOG[t].name),
            cell(FleetSpec::homogeneous(t, None), FaultSpec::None),
        ));
    }
    specs.push(RunSpec::new("fleet/mixed", cell(mixed_fleet(false), FaultSpec::None)));
    specs.push(RunSpec::new(
        "fleet/mixed+bids",
        cell(mixed_fleet(true), FaultSpec::PoolReclamation),
    ));
    specs
}

pub fn run(cfg: &Config) -> anyhow::Result<String> {
    run_scaled(cfg, default_threads(), 6, 100, 12 * 3600)
}

/// Parameterized so tests can run a scaled-down version.
pub fn run_scaled(
    cfg: &Config,
    threads: usize,
    n_wl: usize,
    tasks: usize,
    horizon_s: u64,
) -> anyhow::Result<String> {
    let specs = grid(cfg, n_wl, tasks, horizon_s);
    let results = run_specs(&specs, threads)?;
    let total_tasks = (n_wl * tasks) as f64;
    let mut t = Table::new(vec![
        "fleet",
        "cost ($)",
        "$/task",
        "max inst",
        "TTC (%)",
        "violations",
        "reclaims",
        "requeued",
        "unfulfilled",
    ]);
    let mut csv = String::from(
        "fleet,cost,cost_per_task,max_instances,ttc_pct,violations,reclamations,requeued,unfulfilled\n",
    );
    for (spec, m) in specs.iter().zip(&results) {
        let violations = m.outcomes.iter().filter(|o| !matches!(o.met_ttc(), Some(true))).count();
        let row = [
            spec.label.clone(),
            format!("{:.3}", m.total_cost),
            format!("{:.5}", m.total_cost / total_tasks),
            format!("{}", m.max_instances),
            format!("{:.0}", 100.0 * m.ttc_compliance()),
            format!("{violations}"),
            format!("{}", m.reclamations),
            format!("{}", m.requeued_tasks),
            format!("{}", m.unfulfilled_requests),
        ];
        csv.push_str(&row.join(","));
        csv.push('\n');
        t.row(row.to_vec());
    }
    std::fs::create_dir_all(super::OUT_DIR)?;
    std::fs::write(format!("{}/heterogeneous.csv", super::OUT_DIR), &csv)?;
    let mixed = &results[TYPES.len()];
    let cheapest_homog = results[..TYPES.len()]
        .iter()
        .map(|m| m.total_cost)
        .fold(f64::INFINITY, f64::min);
    let summary = format!(
        "mixed fleet ${:.3} vs cheapest homogeneous ${:.3} ({} cells; CSV in {}/heterogeneous.csv)\n",
        mixed.total_cost,
        cheapest_homog,
        specs.len(),
        super::OUT_DIR,
    );
    let out = format!("{}{summary}", t.render());
    println!("{out}");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaled_sweep_covers_homogeneous_and_mixed_cells() {
        let mut cfg = Config::paper_defaults();
        cfg.use_xla = false;
        cfg.control.n_min = 4.0;
        let out = run_scaled(&cfg, 2, 2, 20, 4 * 3600).unwrap();
        assert!(out.contains("fleet/homogeneous/m3.medium"));
        assert!(out.contains("fleet/homogeneous/m4.10xlarge"));
        assert!(out.contains("fleet/mixed"));
        assert!(out.contains("fleet/mixed+bids"));
    }

    #[test]
    fn grid_cells_are_well_formed() {
        let cfg = Config::paper_defaults();
        let g = grid(&cfg, 3, 10, 3600);
        assert_eq!(g.len(), TYPES.len() + 2);
        assert!(g.iter().all(|s| s.n_tasks() == 30));
        assert!(g.iter().all(|s| !s.scenario.record_traces));
        // every homogeneous cell carries exactly one pool; the mixed
        // cells carry the full catalogue
        for s in &g[..TYPES.len()] {
            assert_eq!(s.scenario.fleet.pools.len(), 1);
        }
        assert_eq!(g[TYPES.len()].scenario.fleet.pools.len(), TYPES.len());
        let bids = &g[TYPES.len() + 1].scenario.fleet;
        assert!(bids.pools.iter().all(|p| p.bid.is_some()));
        assert_eq!(g[TYPES.len() + 1].scenario.fault, FaultSpec::PoolReclamation);
    }
}
