//! Fig. 5: total input size of each of the thirty §V-A workloads.

use crate::config::Config;
use crate::util::table::{ascii_chart, write_csv, Table};
use crate::workload::paper_suite;

pub fn run(cfg: &Config) -> anyhow::Result<String> {
    let suite = paper_suite(cfg.seed);
    let mut t = Table::new(vec!["arrival slot", "workload", "tasks", "input size (MB)"]);
    let mut series: Vec<(f64, f64)> = vec![];
    let mut total_bytes = 0u64;
    let mut total_tasks = 0usize;
    for w in &suite {
        let mb = w.total_bytes() as f64 / 1e6;
        t.row(vec![
            format!("{}", w.id),
            w.name.clone(),
            format!("{}", w.n_tasks()),
            format!("{mb:.1}"),
        ]);
        series.push((w.id as f64, mb));
        total_bytes += w.total_bytes();
        total_tasks += w.n_tasks();
    }
    let chart = ascii_chart(
        "Fig. 5 — input size per workload (MB)",
        &[("size", &series)],
        60,
        12,
    );
    write_csv(&format!("{}/fig5.csv", super::OUT_DIR), "workload", &[("size_mb", &series)])?;
    let summary = format!(
        "total: {} workloads, {} tasks, {:.2} GB of input\n",
        suite.len(),
        total_tasks,
        total_bytes as f64 / 1e9
    );
    let out = format!("{}{}{}", t.render(), chart, summary);
    println!("{out}");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_reports_thirty_workloads() {
        let cfg = Config::paper_defaults();
        let out = run(&cfg).unwrap();
        assert!(out.contains("total: 30 workloads"));
        assert!(std::path::Path::new("out/fig5.csv").exists());
    }
}
