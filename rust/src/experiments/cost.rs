//! Fig. 8 / Fig. 9 / Table III: cumulative billing cost of the full
//! §V-A suite under each scaling method, plus the lower bound.

use crate::config::Config;
use crate::coordinator::PolicyKind;
use crate::estimation::EstimatorKind;
use crate::metrics::RunMetrics;
use crate::platform::{run_experiment, RunOpts};
use crate::util::table::{ascii_chart, fmt_hm, write_csv, Table};
use crate::workload::paper_suite;

/// §V-C experiment 1 TTC: 2 hr 07 min (from the conservative Amazon AS run).
pub const TTC_LONG_S: u64 = 2 * 3600 + 7 * 60;
/// §V-C experiment 2 TTC: 1 hr 37 min (from the aggressive Amazon AS run).
pub const TTC_SHORT_S: u64 = 3600 + 37 * 60;

/// The §V-C comparison set for one TTC setting.
fn methods(ttc: u64) -> Vec<(&'static str, PolicyKind, Option<u64>)> {
    let as_kind = if ttc == TTC_LONG_S { PolicyKind::AmazonAs1 } else { PolicyKind::AmazonAs10 };
    vec![
        ("AIMD", PolicyKind::Aimd, Some(ttc)),
        ("Reactive", PolicyKind::Reactive, Some(ttc)),
        ("MWA", PolicyKind::Mwa, Some(ttc)),
        ("LR", PolicyKind::Lr, Some(ttc)),
        ("Amazon AS", as_kind, None), // AS cannot do TTC-abiding execution
    ]
}

/// One method's run over the suite.
pub fn run_method(
    cfg: &Config,
    policy: PolicyKind,
    ttc: Option<u64>,
) -> anyhow::Result<RunMetrics> {
    // §V-C runs use 5-minute policy evaluation (Amazon AS's native
    // cadence; the paper's monitoring band is 1–5 min)
    let mut cfg = cfg.clone();
    cfg.control.monitor_interval_s = 300;
    let suite = paper_suite(cfg.seed);
    let opts = RunOpts {
        policy,
        estimator: EstimatorKind::Kalman,
        fixed_ttc_s: ttc,
        horizon_s: 16 * 3600,
        ..Default::default()
    };
    run_experiment(cfg.clone(), suite, opts)
}

pub struct FigResult {
    pub report: String,
    /// (method, total cost, max instances, finished_at)
    pub rows: Vec<(String, f64, usize, u64)>,
    pub lb: f64,
}

pub fn run_fig_inner(cfg: &Config, ttc: u64, name: &str) -> anyhow::Result<FigResult> {
    let mut curves: Vec<(String, Vec<(f64, f64)>)> = vec![];
    let mut rows = vec![];
    let mut lb = f64::NAN;
    for (label, policy, ttc_opt) in methods(ttc) {
        let m = run_method(cfg, policy, ttc_opt)?;
        if label == "AIMD" {
            lb = m.lower_bound_cost(cfg.market.base_spot_price);
        }
        rows.push((label.to_string(), m.total_cost, m.max_instances, m.finished_at));
        curves.push((label.to_string(), m.cost_curve_hours()));
    }
    let series: Vec<(&str, &[(f64, f64)])> = curves
        .iter()
        .map(|(n, c)| (n.as_str(), c.as_slice()))
        .collect();
    let chart = ascii_chart(
        &format!("{name} — cumulative cost ($) vs time (h), TTC = {}", fmt_hm(ttc as f64)),
        &series,
        70,
        16,
    );
    write_csv(&format!("{}/{name}.csv", super::OUT_DIR), "hours", &series)?;
    let mut t = Table::new(vec!["method", "total cost ($)", "max instances", "finished"]);
    for (label, cost, maxi, fin) in &rows {
        t.row(vec![
            label.clone(),
            format!("{cost:.3}"),
            format!("{maxi}"),
            fmt_hm(*fin as f64),
        ]);
    }
    t.row(vec!["LB".into(), format!("{lb:.3}"), "-".into(), "-".into()]);
    let aimd = rows[0].1;
    let mut savings = String::new();
    for (label, cost, _, _) in rows.iter().skip(1) {
        savings.push_str(&format!(
            "AIMD saves {:.0}% vs {label}\n",
            100.0 * (cost - aimd) / cost.max(1e-12)
        ));
    }
    savings.push_str(&format!("AIMD is {:.0}% above LB\n", 100.0 * (aimd - lb) / lb.max(1e-12)));
    let report = format!("{chart}{}{savings}", t.render());
    Ok(FigResult { report, rows, lb })
}

pub fn run_fig(cfg: &Config, ttc: u64, name: &str) -> anyhow::Result<String> {
    let r = run_fig_inner(cfg, ttc, name)?;
    println!("{}", r.report);
    Ok(r.report)
}

/// Table III: overall (both experiments summed) cost per method, average
/// reductions, and max instances.
pub fn run_table3(cfg: &Config) -> anyhow::Result<String> {
    let a = run_fig_inner(cfg, TTC_LONG_S, "fig8")?;
    let b = run_fig_inner(cfg, TTC_SHORT_S, "fig9")?;
    let labels = ["AIMD", "Reactive", "MWA", "LR", "Amazon AS"];
    let mut t = Table::new(vec![
        "system",
        "overall cost ($)",
        "cost reduction of AIMD vs (%)",
        "increase vs LB (%)",
        "max instances",
    ]);
    let lb = a.lb + b.lb;
    let total =
        |r: &FigResult, i: usize| -> (f64, usize) { (r.rows[i].1, r.rows[i].2) };
    let (aimd_cost, _) = (total(&a, 0).0 + total(&b, 0).0, 0);
    let mut summary = String::new();
    for (i, label) in labels.iter().enumerate() {
        let cost = total(&a, i).0 + total(&b, i).0;
        let maxi = total(&a, i).1.max(total(&b, i).1);
        let red = if i == 0 { "-".to_string() } else { format!("{:.0}", 100.0 * (cost - aimd_cost) / cost) };
        t.row(vec![
            label.to_string(),
            format!("{cost:.2}"),
            red,
            format!("{:.0}", 100.0 * (cost - lb) / lb),
            format!("{maxi}"),
        ]);
        if i > 0 {
            summary.push_str(&format!(
                "AIMD cost reduction vs {label}: {:.0}%\n",
                100.0 * (cost - aimd_cost) / cost
            ));
        }
    }
    t.row(vec!["LB".into(), format!("{lb:.2}"), "-".into(), "-".into(), "-".into()]);
    let out = format!("{}{}", t.render(), summary);
    println!("{out}");
    Ok(out)
}

#[cfg(test)]
mod tests {
    #[test]
    fn ttc_constants_match_paper() {
        assert_eq!(super::TTC_LONG_S, 7620);
        assert_eq!(super::TTC_SHORT_S, 5820);
    }

    #[test]
    fn methods_cover_comparison_set() {
        let m = super::methods(super::TTC_LONG_S);
        assert_eq!(m.len(), 5);
        assert!(m.iter().any(|(n, _, ttc)| *n == "Amazon AS" && ttc.is_none()));
    }
}
