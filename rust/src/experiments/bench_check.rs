//! `dithen bench-check`: the CI bench-regression gate.
//!
//! Compares two `dithen-bench-report/v1` payloads and exits non-zero
//! when the current sweep throughput (`current.tasks_per_s`) falls
//! below `tolerance × baseline` — so a PR that quietly serializes the
//! sweep harness (a lock on the hot path, a cache that stopped
//! hitting) turns the build red instead of a number in an artifact
//! nobody reads.
//!
//! ```text
//! dithen bench-check --baseline prev.json --current out/bench-ci.json --tolerance 0.8
//! ```
//!
//! Gate semantics (deliberately one-sided and tolerant — CI runners are
//! shared and noisy, so the default 0.8 tolerance flags only >20 %
//! regressions; improvements always pass):
//!
//! * **fail (exit 1)** — both reports are measured, comparable (same
//!   grid) and `current < tolerance × baseline`;
//! * **pass (exit 0)** — comparable and within tolerance;
//! * **skip (exit 0, with a printed reason)** — the baseline is the
//!   committed `pending-measurement` placeholder, has null numbers, or
//!   ran a different grid (`cost-smoke` vs `cost-default` are not
//!   comparable). The gate never fails on an absent history — the
//!   first measured run *creates* the history;
//! * **error (exit ≠ 0 via `Err`)** — the *current* report is missing
//!   or malformed: that's a broken pipeline, not a missing baseline.

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

/// Outcome of one comparison (exit-code mapping in [`run`]).
#[derive(Debug, Clone, PartialEq)]
pub enum Gate {
    /// Comparable and within tolerance: `current ≥ tolerance × baseline`.
    Pass { baseline: f64, current: f64, ratio: f64 },
    /// Comparable and regressed beyond tolerance.
    Fail { baseline: f64, current: f64, ratio: f64 },
    /// No comparable baseline; the reason is printed, the gate passes.
    Skip { reason: String },
}

fn tasks_per_s(doc: &Json) -> Option<f64> {
    doc.get("current")?.get("tasks_per_s")?.as_f64()
}

fn grid(doc: &Json) -> Option<&str> {
    doc.get("grid")?.as_str()
}

fn is_report(doc: &Json) -> bool {
    doc.get("schema").and_then(|s| s.as_str()) == Some("dithen-bench-report/v1")
}

/// Pure comparison over parsed reports (IO-free; unit-tested).
pub fn check(baseline: &Json, current: &Json, tolerance: f64) -> Result<Gate> {
    anyhow::ensure!(
        tolerance > 0.0 && tolerance.is_finite(),
        "tolerance must be a positive ratio (got {tolerance})"
    );
    anyhow::ensure!(is_report(current), "current report is not dithen-bench-report/v1");
    let cur =
        tasks_per_s(current).context("current report carries no measured current.tasks_per_s")?;
    anyhow::ensure!(cur.is_finite() && cur > 0.0, "current tasks_per_s is not a positive number");
    if !is_report(baseline) {
        return Ok(Gate::Skip { reason: "baseline is not a dithen-bench-report/v1 payload".into() });
    }
    if baseline.get("status").and_then(|s| s.as_str()) == Some("pending-measurement") {
        return Ok(Gate::Skip {
            reason: "baseline is the pending-measurement placeholder (no history yet)".into(),
        });
    }
    let base = match tasks_per_s(baseline) {
        Some(b) if b.is_finite() && b > 0.0 => b,
        _ => {
            return Ok(Gate::Skip {
                reason: "baseline carries no measured current.tasks_per_s".into(),
            })
        }
    };
    match (grid(baseline), grid(current)) {
        (Some(bg), Some(cg)) if bg != cg => {
            return Ok(Gate::Skip {
                reason: format!("baseline grid '{bg}' != current grid '{cg}' (not comparable)"),
            })
        }
        _ => {}
    }
    let ratio = cur / base;
    if ratio < tolerance {
        Ok(Gate::Fail { baseline: base, current: cur, ratio })
    } else {
        Ok(Gate::Pass { baseline: base, current: cur, ratio })
    }
}

fn load(path: &str) -> Result<Json> {
    let body =
        std::fs::read_to_string(path).with_context(|| format!("reading bench report {path}"))?;
    json::parse(&body).map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))
}

/// File-level entry point; returns the process exit code.
pub fn run(baseline_path: &str, current_path: &str, tolerance: f64) -> Result<i32> {
    let current = load(current_path)?;
    // an unreadable baseline is a skip (first run / expired artifact),
    // an unreadable current report is an error (broken pipeline)
    let gate = match load(baseline_path) {
        Ok(baseline) => check(&baseline, &current, tolerance)?,
        Err(e) => {
            check(&Json::Null, &current, tolerance)?; // still validate current
            Gate::Skip { reason: format!("baseline unreadable: {e:#}") }
        }
    };
    match gate {
        Gate::Pass { baseline, current, ratio } => {
            println!(
                "bench-check PASS: {current:.1} tasks/s vs baseline {baseline:.1} \
                 ({:+.1} %, tolerance {:.0} %)",
                100.0 * (ratio - 1.0),
                100.0 * tolerance,
            );
            Ok(0)
        }
        Gate::Fail { baseline, current, ratio } => {
            eprintln!(
                "bench-check FAIL: {current:.1} tasks/s is {:.1} % of baseline {baseline:.1} \
                 (tolerance {:.0} %) — sweep throughput regressed",
                100.0 * ratio,
                100.0 * tolerance,
            );
            Ok(1)
        }
        Gate::Skip { reason } => {
            println!("bench-check SKIP (gate passes): {reason}");
            Ok(0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(grid: &str, tps: f64) -> Json {
        json::parse(&format!(
            "{{\"schema\": \"dithen-bench-report/v1\", \"grid\": \"{grid}\", \
              \"current\": {{\"tasks_per_s\": {tps}}}}}"
        ))
        .unwrap()
    }

    #[test]
    fn within_tolerance_passes() {
        let base = report("cost-smoke", 1000.0);
        // 15 % down on a 20 % budget: pass
        let cur = report("cost-smoke", 850.0);
        match check(&base, &cur, 0.8).unwrap() {
            Gate::Pass { ratio, .. } => assert!((ratio - 0.85).abs() < 1e-9),
            other => panic!("expected pass, got {other:?}"),
        }
        // improvements always pass
        assert!(matches!(
            check(&base, &report("cost-smoke", 5000.0), 0.8).unwrap(),
            Gate::Pass { .. }
        ));
    }

    #[test]
    fn regression_beyond_tolerance_fails() {
        let base = report("cost-smoke", 1000.0);
        let cur = report("cost-smoke", 700.0);
        match check(&base, &cur, 0.8).unwrap() {
            Gate::Fail { ratio, .. } => assert!((ratio - 0.7).abs() < 1e-9),
            other => panic!("expected fail, got {other:?}"),
        }
    }

    #[test]
    fn placeholder_baseline_skips() {
        // the committed BENCH_PR1.json shape: right schema, null numbers
        let base = json::parse(
            "{\"schema\": \"dithen-bench-report/v1\", \"grid\": \"cost-default\", \
              \"status\": \"pending-measurement\", \"current\": {\"tasks_per_s\": null}}",
        )
        .unwrap();
        let cur = report("cost-smoke", 100.0);
        assert!(matches!(check(&base, &cur, 0.8).unwrap(), Gate::Skip { .. }));
    }

    #[test]
    fn null_baseline_numbers_skip_even_without_status() {
        let base = json::parse(
            "{\"schema\": \"dithen-bench-report/v1\", \"grid\": \"cost-smoke\", \
              \"current\": {\"tasks_per_s\": null}}",
        )
        .unwrap();
        assert!(matches!(
            check(&base, &report("cost-smoke", 100.0), 0.8).unwrap(),
            Gate::Skip { .. }
        ));
    }

    #[test]
    fn mismatched_grids_skip() {
        let base = report("cost-default", 1000.0);
        let cur = report("cost-smoke", 10.0);
        match check(&base, &cur, 0.8).unwrap() {
            Gate::Skip { reason } => assert!(reason.contains("not comparable")),
            other => panic!("expected skip, got {other:?}"),
        }
    }

    #[test]
    fn broken_current_report_is_an_error_not_a_skip() {
        let base = report("cost-smoke", 1000.0);
        let no_schema = json::parse("{\"current\": {\"tasks_per_s\": 5.0}}").unwrap();
        assert!(check(&base, &no_schema, 0.8).is_err());
        let null_tps = json::parse(
            "{\"schema\": \"dithen-bench-report/v1\", \"current\": {\"tasks_per_s\": null}}",
        )
        .unwrap();
        assert!(check(&base, &null_tps, 0.8).is_err());
        assert!(check(&base, &report("cost-smoke", 100.0), 0.0).is_err(), "zero tolerance");
        assert!(check(&base, &report("cost-smoke", 100.0), f64::NAN).is_err());
    }

    #[test]
    fn run_maps_gate_to_exit_codes() {
        let dir = std::env::temp_dir().join(format!("dithen-bench-check-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = |name: &str, body: &str| {
            let path = dir.join(name);
            std::fs::write(&path, body).unwrap();
            path.to_str().unwrap().to_string()
        };
        let base = p(
            "base.json",
            "{\"schema\": \"dithen-bench-report/v1\", \"grid\": \"g\", \
              \"current\": {\"tasks_per_s\": 1000.0}}",
        );
        let good = p(
            "good.json",
            "{\"schema\": \"dithen-bench-report/v1\", \"grid\": \"g\", \
              \"current\": {\"tasks_per_s\": 900.0}}",
        );
        let bad = p(
            "bad.json",
            "{\"schema\": \"dithen-bench-report/v1\", \"grid\": \"g\", \
              \"current\": {\"tasks_per_s\": 100.0}}",
        );
        assert_eq!(run(&base, &good, 0.8).unwrap(), 0);
        assert_eq!(run(&base, &bad, 0.8).unwrap(), 1);
        // missing baseline file: skip, gate passes
        let missing = dir.join("nope.json").to_str().unwrap().to_string();
        assert_eq!(run(&missing, &good, 0.8).unwrap(), 0);
        // missing *current* file: hard error
        assert!(run(&base, &missing, 0.8).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
