//! Fig. 10 / Fig. 11: cumulative cost of the §V-E Split–Merge workloads
//! (deep-CNN ensemble classification; Gutenberg word histogram) under
//! Dithen's AIMD vs Amazon AS, with the lower bound.

use crate::config::Config;
use crate::coordinator::PolicyKind;
use crate::platform::{run_experiment, RunOpts};
use crate::util::table::{ascii_chart, fmt_hm, write_csv, Table};
use crate::workload::{cnn_splitmerge, wordcount_splitmerge, WorkloadSpec};

/// §V-E TTCs: 1 hr 35 min (CNN) and 1 hr 05 min (word histogram); the
/// split stage gets 90 % of the overall TTC.
pub const TTC_CNN_S: u64 = 3600 + 35 * 60;
pub const TTC_WORDCOUNT_S: u64 = 3600 + 5 * 60;

fn run_one(cfg: &Config, spec: WorkloadSpec, ttc: u64, name: &str) -> anyhow::Result<String> {
    let split_ttc = (ttc as f64 * 0.9) as u64;
    let mut curves: Vec<(String, Vec<(f64, f64)>)> = vec![];
    let mut rows = vec![];
    let mut lb = 0.0;
    for (label, policy, ttc_opt) in [
        ("AIMD", PolicyKind::Aimd, Some(split_ttc)),
        ("Amazon AS", PolicyKind::AmazonAs1, None),
    ] {
        let m = run_experiment(
            cfg.clone(),
            vec![spec.clone()],
            RunOpts {
                policy,
                fixed_ttc_s: ttc_opt,
                horizon_s: 12 * 3600,
                ..Default::default()
            },
        )?;
        if label == "AIMD" {
            lb = m.lower_bound_cost(cfg.market.base_spot_price);
        }
        rows.push((label, m.total_cost, m.max_instances, m.finished_at));
        curves.push((label.to_string(), m.cost_curve_hours()));
    }
    let series: Vec<(&str, &[(f64, f64)])> =
        curves.iter().map(|(n, c)| (n.as_str(), c.as_slice())).collect();
    let chart = ascii_chart(
        &format!("{name} — cumulative cost ($), TTC = {}", fmt_hm(ttc as f64)),
        &series,
        70,
        14,
    );
    write_csv(&format!("{}/{name}.csv", super::OUT_DIR), "hours", &series)?;
    let mut t = Table::new(vec!["method", "cost ($)", "max instances", "finished"]);
    for (label, cost, maxi, fin) in &rows {
        t.row(vec![
            label.to_string(),
            format!("{cost:.3}"),
            format!("{maxi}"),
            fmt_hm(*fin as f64),
        ]);
    }
    t.row(vec!["LB".into(), format!("{lb:.3}"), "-".into(), "-".into()]);
    let aimd = rows[0].1;
    let as_cost = rows[1].1;
    let summary = format!(
        "Amazon AS costs {:.2}x AIMD; AIMD is {:.0}% above LB\n",
        as_cost / aimd.max(1e-12),
        100.0 * (aimd - lb) / lb.max(1e-12)
    );
    let out = format!("{chart}{}{summary}", t.render());
    println!("{out}");
    Ok(out)
}

pub fn run_cnn(cfg: &Config) -> anyhow::Result<String> {
    run_one(cfg, cnn_splitmerge(cfg.seed), TTC_CNN_S, "fig10")
}

pub fn run_wordcount(cfg: &Config) -> anyhow::Result<String> {
    run_one(cfg, wordcount_splitmerge(cfg.seed), TTC_WORDCOUNT_S, "fig11")
}

#[cfg(test)]
mod tests {
    #[test]
    fn ttc_constants_match_paper() {
        assert_eq!(super::TTC_CNN_S, 5700);
        assert_eq!(super::TTC_WORDCOUNT_S, 3900);
    }
}
