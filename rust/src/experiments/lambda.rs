//! Table IV: average per-image cost of the three ImageMagick functions on
//! Amazon Lambda vs Dithen, over 25 000 images each.
//!
//! Lambda is evaluated two ways that bracket the paper's measurement:
//!
//! * **analytic** — the §V-D pricing model (fractional core = memory
//!   share, 100 ms billing quanta, per-request fee) applied to each
//!   task's full-core duration: pure Lambda, one invocation per image,
//!   no batching (the paper's accounting);
//! * **sim loop** — the same workload executed end to end through the
//!   platform with [`crate::cloud::BackendKind::Lambda`]: the scenario
//!   API's Lambda backend runs the identical scheduling loop (chunking,
//!   estimators, scaling) on fractional-core usage-billed slots, so the
//!   §V-D baseline is no longer a separate analytic path.
//!
//! Dithen: a platform run of the same workload on the spot backend, TTC
//! tuned to roughly match Lambda's makespan (the paper matched execution
//! times).

use crate::cloud::lambda::{core_fraction, price_batch};
use crate::cloud::BackendKind;
use crate::config::Config;
use crate::coordinator::PolicyKind;
use crate::platform::ScenarioBuilder;
use crate::util::table::Table;
use crate::workload::{lambda_suite, WorkloadSpec};

pub const N_IMAGES: usize = 25_000;

pub fn run(cfg: &Config) -> anyhow::Result<String> {
    run_scaled(cfg, N_IMAGES)
}

/// `n_images` is parameterized so tests can run a scaled-down version.
pub fn run_scaled(cfg: &Config, n_images: usize) -> anyhow::Result<String> {
    let suite = lambda_suite(cfg.seed, n_images);
    let mut t = Table::new(vec![
        "function",
        "Lambda cost ($/img)",
        "Lambda sim ($/img)",
        "Dithen cost ($/img)",
        "ratio",
    ]);
    let mut ratios = vec![];
    let mut lambda_total = 0.0;
    let mut dithen_total = 0.0;
    for spec in &suite {
        // Lambda, analytic: price each task's true full-core duration
        let durations: Vec<f64> = spec.tasks.iter().map(|t| t.true_cus).collect();
        let (l_total, l_per) = price_batch(&cfg.lambda, &durations);

        // Dithen side: run the workload alone; TTC ≈ Lambda makespan
        // (Lambda executes with wide parallelism, so its makespan is set
        // by invocation throughput; the paper tuned Dithen to match —
        // we give Dithen the same wall-clock budget: total fractional-core
        // time spread over ~N_w,max instances, floored at 20 min)
        let frac = core_fraction(&cfg.lambda);
        let lambda_wall: f64 = durations.iter().sum::<f64>() / frac / cfg.control.n_w_max;
        let ttc = (lambda_wall.ceil() as u64).max(1200);
        let name = spec.name.clone();
        let one_workload =
            |spec: &WorkloadSpec| vec![WorkloadSpec { id: 0, ..spec.clone() }];
        let run_on = |backend: BackendKind| {
            ScenarioBuilder::new(cfg.clone())
                .workloads(one_workload(spec))
                .policy(PolicyKind::Aimd)
                .fixed_ttc(Some(ttc))
                .horizon(24 * 3600)
                .backend(backend)
                .record_traces(false)
                .build()
                .run()
        };
        // Lambda through the same scheduling loop (fractional cores,
        // usage billing) — the §V-D baseline without its own code path
        let l_sim = run_on(BackendKind::Lambda)?;
        let l_sim_per = l_sim.total_cost / n_images as f64;
        // Dithen proper: whole-core spot instances
        let m = run_on(BackendKind::Spot)?;
        let d_per = m.total_cost / n_images as f64;
        let ratio = l_per / d_per.max(1e-12);
        ratios.push(ratio);
        lambda_total += l_total;
        dithen_total += m.total_cost;
        t.row(vec![
            name,
            format!("{l_per:.2e}"),
            format!("{l_sim_per:.2e}"),
            format!("{d_per:.2e}"),
            format!("{ratio:.2}"),
        ]);
    }
    let overall = lambda_total / dithen_total.max(1e-12);
    t.row(vec![
        "Overall Average".into(),
        format!("{:.2e}", lambda_total / (3 * n_images) as f64),
        "-".into(),
        format!("{:.2e}", dithen_total / (3 * n_images) as f64),
        format!("{overall:.2}"),
    ]);
    let summary = format!(
        "Dithen runs the ImageMagick workloads at {overall:.2}x lower cost than Lambda \
         ({:.0}% reduction)\n",
        100.0 * (1.0 - 1.0 / overall.max(1e-12))
    );
    let out = format!("{}{}", t.render(), summary);
    println!("{out}");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaled_run_produces_expected_shape() {
        let mut cfg = Config::paper_defaults();
        cfg.use_xla = false;
        cfg.control.n_min = 4.0;
        let out = run_scaled(&cfg, 800).unwrap();
        assert!(out.contains("im-blur"));
        assert!(out.contains("Overall Average"));
        assert!(out.contains("Lambda sim"));
    }
}
