//! Table IV: average per-image cost of the three ImageMagick functions on
//! Amazon Lambda vs Dithen, over 25 000 images each.
//!
//! Lambda: the §V-D pricing model (fractional core = memory share, 100 ms
//! billing quanta, per-request fee) applied to each task's full-core
//! duration. Dithen: a platform run of the same workload, TTC tuned to
//! roughly match Lambda's makespan (the paper matched execution times).

use crate::config::Config;
use crate::cloud::lambda::{core_fraction, price_batch};
use crate::coordinator::PolicyKind;
use crate::platform::{run_experiment, RunOpts};
use crate::util::table::Table;
use crate::workload::lambda_suite;

pub const N_IMAGES: usize = 25_000;

pub fn run(cfg: &Config) -> anyhow::Result<String> {
    run_scaled(cfg, N_IMAGES)
}

/// `n_images` is parameterized so tests can run a scaled-down version.
pub fn run_scaled(cfg: &Config, n_images: usize) -> anyhow::Result<String> {
    let suite = lambda_suite(cfg.seed, n_images);
    let mut t = Table::new(vec![
        "function",
        "Lambda cost ($/img)",
        "Dithen cost ($/img)",
        "ratio",
    ]);
    let mut ratios = vec![];
    let mut lambda_total = 0.0;
    let mut dithen_total = 0.0;
    for spec in &suite {
        // Lambda side: price each task's true full-core duration
        let durations: Vec<f64> = spec.tasks.iter().map(|t| t.true_cus).collect();
        let (l_total, l_per) = price_batch(&cfg.lambda, &durations);

        // Dithen side: run the workload alone; TTC ≈ Lambda makespan
        // (Lambda executes with wide parallelism, so its makespan is set
        // by invocation throughput; the paper tuned Dithen to match —
        // we give Dithen the same wall-clock budget: total fractional-core
        // time spread over ~N_w,max instances, floored at 20 min)
        let frac = core_fraction(&cfg.lambda);
        let lambda_wall: f64 = durations.iter().sum::<f64>() / frac / cfg.control.n_w_max;
        let ttc = (lambda_wall.ceil() as u64).max(1200);
        let spec_run = spec.clone();
        let name = spec.name.clone();
        let m = run_experiment(
            cfg.clone(),
            vec![crate::workload::WorkloadSpec { id: 0, ..spec_run }],
            RunOpts {
                policy: PolicyKind::Aimd,
                fixed_ttc_s: Some(ttc),
                horizon_s: 24 * 3600,
                ..Default::default()
            },
        )?;
        let d_per = m.total_cost / n_images as f64;
        let ratio = l_per / d_per.max(1e-12);
        ratios.push(ratio);
        lambda_total += l_total;
        dithen_total += m.total_cost;
        t.row(vec![
            name,
            format!("{l_per:.2e}"),
            format!("{d_per:.2e}"),
            format!("{ratio:.2}"),
        ]);
    }
    let overall = lambda_total / dithen_total.max(1e-12);
    t.row(vec![
        "Overall Average".into(),
        format!("{:.2e}", lambda_total / (3 * n_images) as f64),
        format!("{:.2e}", dithen_total / (3 * n_images) as f64),
        format!("{overall:.2}"),
    ]);
    let summary = format!(
        "Dithen runs the ImageMagick workloads at {overall:.2}x lower cost than Lambda \
         ({:.0}% reduction)\n",
        100.0 * (1.0 - 1.0 / overall.max(1e-12))
    );
    let out = format!("{}{}", t.render(), summary);
    println!("{out}");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaled_run_produces_expected_shape() {
        let mut cfg = Config::paper_defaults();
        cfg.use_xla = false;
        cfg.control.n_min = 4.0;
        let out = run_scaled(&cfg, 800).unwrap();
        assert!(out.contains("im-blur"));
        assert!(out.contains("Overall Average"));
    }
}
