//! `dithen bench-report`: measure end-to-end simulated-tasks/second on
//! the default cost-experiment grid and write a machine-readable JSON
//! report (`BENCH_PR1.json` seeds the perf trajectory; later PRs append
//! `BENCH_PR<n>.json` against the same schema and the same grid).
//!
//! Two comparisons, both measured in the same process and recorded in
//! the same file:
//!
//! 1. **end-to-end**: the grid run sequentially (1 thread — the only
//!    mode the pre-refactor harness had) vs. the parallel runner at
//!    every requested width (`--threads` takes a comma list; one timed
//!    pass per width — the `sweep_tasks_per_s` scaling curve) vs. the
//!    PR-5 lockstep batched executor at the max width
//!    (`batched_tasks_per_s`). Tasks/second counts every simulated
//!    task of every run.
//! 2. **task-DB microbench**: the identical insert→claim→complete
//!    lifecycle plus per-tick query mix on the flat-arena [`TaskDb`]
//!    vs. the seed's BTreeMap store ([`legacy::LegacyTaskDb`]), which
//!    is kept in-tree precisely to keep this baseline measurable.
//!
//! Every parallel and batched pass is asserted equal to the sequential
//! results before anything is written — a bench run doubles as a
//! determinism check.

use std::time::Instant;

use crate::config::Config;
use crate::coordinator::PolicyKind;
use crate::db::{legacy::LegacyTaskDb, TaskDb, TaskStatus};
use crate::estimation::BankCache;
use crate::platform::RunOpts;
use crate::util::rng::Rng;
use crate::workload::{App, WorkloadSpec};

use super::parallel::{cost_grid, run_specs_with_cache, RunSpec};

/// Everything the report records.
#[derive(Debug, Clone)]
pub struct BenchReport {
    pub grid: &'static str,
    pub runs: usize,
    pub tasks_total: usize,
    pub seq_wall_s: f64,
    /// Timed parallel passes as `(threads, wall_s)` in ascending width
    /// (`bench-report --threads 1,2,4,8` measures one pass per width
    /// above 1; the 1-thread baseline is `seq_wall_s`). The last entry
    /// is the max width — `current.tasks_per_s` in the JSON, what
    /// `bench-check` gates on (like-for-like at the max width).
    pub widths: Vec<(usize, f64)>,
    /// Wall time of the lockstep batched executor pass
    /// (`experiments::batched`) over the same grid at the max width.
    pub batched_wall_s: f64,
    /// The PR-6 sparse pass: cells of [`sparse_grid`] (long horizon,
    /// low arrival rate — the regime where the event-driven tick
    /// skipper earns its keep), with the executed/skipped tick split
    /// summed across cells. Skipped runs are asserted bit-identical to
    /// dense-tick twins before anything is written.
    pub sparse_runs: usize,
    pub sparse_wall_s: f64,
    pub sparse_ticks_executed: u64,
    pub sparse_ticks_skipped: u64,
    /// The PR-8 streaming pass: the million-task cell of
    /// [`super::parallel::stream_grid`], run with lazy workload
    /// materialization and shard retirement. `stream_peak_live_shards`
    /// / `stream_peak_arena_bytes` are the residency receipts: they
    /// track the *arrival window*, not the task total, which is what
    /// lets a 1M-task run fit in CI.
    pub stream_tasks: usize,
    pub stream_wall_s: f64,
    pub stream_peak_live_shards: usize,
    pub stream_peak_arena_bytes: usize,
    pub db_tasks: usize,
    pub db_legacy_ops_per_s: f64,
    pub db_arena_ops_per_s: f64,
    /// Bank-cache lookups served from a cached variant across every
    /// sweep pass (all passes share one cache, like a real multi-grid
    /// session).
    pub cache_hits: u64,
    /// Bank-cache lookups that resolved a backend from scratch.
    pub cold_builds: u64,
}

impl BenchReport {
    /// The widest measured thread count (1 when only the sequential
    /// baseline ran).
    pub fn threads(&self) -> usize {
        self.widths.last().map(|&(t, _)| t).unwrap_or(1)
    }
    fn par_wall_s(&self) -> f64 {
        self.widths.last().map(|&(_, w)| w).unwrap_or(self.seq_wall_s)
    }
    pub fn seq_tasks_per_s(&self) -> f64 {
        self.tasks_total as f64 / self.seq_wall_s.max(1e-9)
    }
    pub fn par_tasks_per_s(&self) -> f64 {
        self.tasks_total as f64 / self.par_wall_s().max(1e-9)
    }
    pub fn batched_tasks_per_s(&self) -> f64 {
        self.tasks_total as f64 / self.batched_wall_s.max(1e-9)
    }
    pub fn parallel_speedup(&self) -> f64 {
        self.par_tasks_per_s() / self.seq_tasks_per_s().max(1e-9)
    }
    pub fn db_speedup(&self) -> f64 {
        self.db_arena_ops_per_s / self.db_legacy_ops_per_s.max(1e-9)
    }
    pub fn stream_tasks_per_s(&self) -> f64 {
        self.stream_tasks as f64 / self.stream_wall_s.max(1e-9)
    }

    /// The tasks/s-by-thread-count series: the measured sweep
    /// throughput at 1 thread plus every requested width — a real
    /// scaling curve when `--threads` is a comma list, not just two
    /// points. Cross-report tooling reads this to track scaling.
    pub fn sweep_series(&self) -> Vec<(usize, f64)> {
        let mut series = vec![(1, self.seq_tasks_per_s())];
        for &(t, wall) in &self.widths {
            series.push((t, self.tasks_total as f64 / wall.max(1e-9)));
        }
        series
    }

    /// Serialize (no serde in the vendor set; the schema is flat).
    pub fn to_json(&self) -> String {
        let series = self
            .sweep_series()
            .iter()
            .map(|&(t, tps)| format!("{{\"threads\": {t}, \"tasks_per_s\": {tps:.1}}}"))
            .collect::<Vec<_>>()
            .join(", ");
        format!(
            "{{\n\
             \x20 \"schema\": \"dithen-bench-report/v1\",\n\
             \x20 \"grid\": \"{grid}\",\n\
             \x20 \"runs\": {runs},\n\
             \x20 \"threads\": {threads},\n\
             \x20 \"tasks_simulated_total\": {tasks},\n\
             \x20 \"cache\": {{\"cache_hits\": {hits}, \"cold_builds\": {cold}}},\n\
             \x20 \"sweep_tasks_per_s\": [{series}],\n\
             \x20 \"batched_tasks_per_s\": {btp:.1},\n\
             \x20 \"sparse\": {{\"runs\": {sruns}, \"wall_s\": {sws:.3}, \
             \"ticks_executed\": {ste}, \"ticks_skipped\": {sts}}},\n\
             \x20 \"stream\": {{\"tasks\": {mtk}, \"wall_s\": {mws:.3}, \
             \"tasks_per_s\": {mtps:.1}, \"peak_live_shards\": {mpls}, \
             \"peak_arena_bytes\": {mpab}}},\n\
             \x20 \"baseline\": {{\n\
             \x20   \"mode\": \"sequential-1-thread (pre-refactor harness had no parallel runner)\",\n\
             \x20   \"wall_s\": {sw:.3},\n\
             \x20   \"tasks_per_s\": {stp:.1},\n\
             \x20   \"db_impl\": \"legacy-btreemap (seed TaskDb, kept at src/db/legacy.rs)\",\n\
             \x20   \"db_tasks\": {dbt},\n\
             \x20   \"db_lifecycle_ops_per_s\": {dl:.0}\n\
             \x20 }},\n\
             \x20 \"current\": {{\n\
             \x20   \"mode\": \"parallel runner\",\n\
             \x20   \"wall_s\": {pw:.3},\n\
             \x20   \"tasks_per_s\": {ptp:.1},\n\
             \x20   \"speedup_vs_baseline\": {spd:.2},\n\
             \x20   \"db_impl\": \"flat-arena + intrusive status lists\",\n\
             \x20   \"db_tasks\": {dbt},\n\
             \x20   \"db_lifecycle_ops_per_s\": {da:.0},\n\
             \x20   \"db_speedup_vs_legacy\": {dspd:.2}\n\
             \x20 }}\n\
             }}\n",
            grid = self.grid,
            runs = self.runs,
            sruns = self.sparse_runs,
            sws = self.sparse_wall_s,
            ste = self.sparse_ticks_executed,
            sts = self.sparse_ticks_skipped,
            mtk = self.stream_tasks,
            mws = self.stream_wall_s,
            mtps = self.stream_tasks_per_s(),
            mpls = self.stream_peak_live_shards,
            mpab = self.stream_peak_arena_bytes,
            threads = self.threads(),
            hits = self.cache_hits,
            cold = self.cold_builds,
            dbt = self.db_tasks,
            tasks = self.tasks_total,
            btp = self.batched_tasks_per_s(),
            sw = self.seq_wall_s,
            stp = self.seq_tasks_per_s(),
            dl = self.db_legacy_ops_per_s,
            pw = self.par_wall_s(),
            ptp = self.par_tasks_per_s(),
            spd = self.parallel_speedup(),
            da = self.db_arena_ops_per_s,
            dspd = self.db_speedup(),
        )
    }
}

/// One lifecycle + tick-query pass over `n` tasks (2 media types) —
/// the op mix a GCI run puts through the store. Returns a checksum so
/// the optimizer cannot elide the queries.
fn drive_arena(n: usize, ticks: usize) -> f64 {
    let mut db = TaskDb::new();
    for t in 0..n {
        db.insert(0, t % 2, t);
    }
    db.reserve_measurements(0);
    let mut acc = 0.0f64;
    let per_tick = (n / ticks.max(1)).max(1);
    let mut t = 0usize;
    for tick in 0..ticks {
        let now = (tick as u64 + 1) * 60;
        let hi = (t + per_tick).min(n);
        while t < hi {
            db.claim((0, t), 1);
            db.complete((0, t), 1.5, now, 0);
            t += 1;
        }
        for k in 0..2 {
            acc += db.remaining_slice(0).get(k).copied().unwrap_or(0) as f64;
            let win = db.measurements_window(0, k, now.saturating_sub(60), now);
            acc += win.iter().map(|&(_, c)| c).sum::<f64>();
        }
        acc += db.count_status(0, TaskStatus::Pending) as f64;
        acc += db.status_iter(0, TaskStatus::Pending).take(32).sum::<usize>() as f64;
    }
    acc
}

/// The same op mix on the seed store (its measurement window is the
/// full-table scan the refactor removed).
fn drive_legacy(n: usize, ticks: usize) -> f64 {
    let mut db = LegacyTaskDb::new();
    for t in 0..n {
        db.insert(0, t % 2, t);
    }
    let mut acc = 0.0f64;
    let per_tick = (n / ticks.max(1)).max(1);
    let mut t = 0usize;
    for tick in 0..ticks {
        let now = (tick as u64 + 1) * 60;
        let hi = (t + per_tick).min(n);
        while t < hi {
            db.claim((0, t), 1);
            db.complete((0, t), 1.5, now, 0);
            t += 1;
        }
        for k in 0..2 {
            acc += db.remaining_by_type(0, 2)[k];
            acc += db
                .measurements_between(0, k, now.saturating_sub(60), now)
                .iter()
                .sum::<f64>();
        }
        acc += db.count_status(0, TaskStatus::Pending) as f64;
        acc += db.first_with_status(0, TaskStatus::Pending, 32).iter().sum::<usize>() as f64;
    }
    acc
}

fn ops_per_s(mut f: impl FnMut() -> f64, ops: usize) -> f64 {
    // one warm-up, then best-of-3 timed passes
    std::hint::black_box(f());
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t0 = Instant::now();
        std::hint::black_box(f());
        best = best.min(t0.elapsed().as_secs_f64());
    }
    ops as f64 / best.max(1e-9)
}

/// A reduced grid for CI smoke runs (`--smoke`, also `dithen sweep
/// smoke`): 4 policies over a tiny 3-workload suite with a short
/// horizon — seconds, not minutes. Exercises the same code paths (grid
/// fan-out, determinism assert, JSON write) without the full
/// paper-suite cost.
pub(crate) fn smoke_grid(cfg: &Config) -> Vec<RunSpec> {
    let mut base = cfg.clone();
    base.control.monitor_interval_s = 300;
    base.control.n_min = 4.0;
    let rng = Rng::new(base.seed);
    let suite: Vec<WorkloadSpec> = (0..3)
        .map(|i| WorkloadSpec::generate(i, App::FaceDetection, 40, None, &rng))
        .collect();
    [
        ("aimd", PolicyKind::Aimd, Some(3600)),
        ("reactive", PolicyKind::Reactive, Some(3600)),
        ("mwa", PolicyKind::Mwa, Some(3600)),
        ("amazon-as", PolicyKind::AmazonAs1, None),
    ]
    .into_iter()
    .map(|(name, policy, fixed_ttc_s)| {
        RunSpec::from_opts(
            format!("smoke/{name}"),
            base.clone(),
            suite.clone(),
            RunOpts {
                policy,
                fixed_ttc_s,
                arrival_interval_s: 60,
                horizon_s: 6 * 3600,
                record_traces: false, // sweep-style: traces are never read
                ..Default::default()
            },
        )
    })
    .collect()
}

/// The PR-6 sparse grid (`dithen sweep sparse`, and the bench-report
/// sparse pass): long horizon, low arrival rate — workloads finish
/// well before the next one arrives, so most monitoring instants fall
/// in idle stretches the event-driven tick skipper can fast-forward.
/// A market-reclamation cell is included so the skip horizon's
/// fault/price legs get exercised, not just the billing leg.
pub(crate) fn sparse_grid(cfg: &Config) -> Vec<RunSpec> {
    use crate::platform::{ArrivalProcess, FaultSpec, ScenarioBuilder};
    let mut base = cfg.clone();
    base.use_xla = false;
    base.control.monitor_interval_s = 300;
    base.control.n_min = 4.0;
    let rng = Rng::new(base.seed);
    let suite: Vec<WorkloadSpec> = (0..3)
        .map(|i| WorkloadSpec::generate(i, App::FaceDetection, 15, None, &rng))
        .collect();
    let cell = |name: &str| {
        (
            format!("sparse/{name}"),
            ScenarioBuilder::new(base.clone())
                .workloads(suite.clone())
                .fixed_ttc(Some(3600))
                .arrivals(ArrivalProcess::FixedInterval { interval_s: 5400 })
                .horizon(16 * 3600)
                .record_traces(false),
        )
    };
    let mut specs = vec![];
    for policy in [PolicyKind::Aimd, PolicyKind::Reactive] {
        let (label, builder) = cell(&format!("{policy:?}").to_lowercase());
        specs.push(RunSpec::new(label, builder.policy(policy).build()));
    }
    let (label, builder) = cell("reclaim");
    specs.push(RunSpec::new(
        label,
        builder.fault(FaultSpec::SpotReclamation { bid: 0.0082 }).build(),
    ));
    specs
}

/// Run the bench and write the JSON report to `out_path`. `smoke`
/// swaps the full cost grid for [`smoke_grid`] (CI-sized). `threads`
/// is the requested width *list* (`--threads 1,2,4,8`): the 1-thread
/// baseline is always measured, every listed width above 1 gets its
/// own timed pass (a real scaling curve in `sweep_tasks_per_s`), and
/// the lockstep batched executor is timed at the max width. Every pass
/// is asserted bit-identical to the sequential baseline before
/// anything is written — a bench run doubles as a determinism check
/// for the parallel *and* the batched path.
pub fn run(cfg: &Config, threads: &[usize], out_path: &str, smoke: bool) -> anyhow::Result<String> {
    let mut cfg = cfg.clone();
    cfg.use_xla = false; // backend-independent numbers (see bench_bank)
    let grid = if smoke { smoke_grid(&cfg) } else { cost_grid(&cfg) };
    let runs = grid.len();
    let tasks_total: usize = grid.iter().map(|s| s.n_tasks()).sum();
    let mut widths: Vec<usize> = threads.iter().copied().filter(|&t| t > 1).collect();
    widths.sort_unstable();
    widths.dedup();

    // one dedicated cache across all passes, so the recorded hit/cold
    // counts are attributable to exactly this bench run; warmed first
    // so cold-build cost (XLA manifest parse + compile) lands in
    // no timed pass — otherwise it would all fall on the 1-thread
    // baseline and inflate the reported speedup
    let cache = BankCache::new();
    for spec in &grid {
        spec.scenario.bank_variant(&cache);
    }

    eprintln!("bench-report: {runs} runs / {tasks_total} tasks, sequential baseline...");
    let t0 = Instant::now();
    let seq = run_specs_with_cache(&grid, 1, &cache)?;
    let seq_wall_s = t0.elapsed().as_secs_f64();

    let mut measured: Vec<(usize, f64)> = Vec::with_capacity(widths.len());
    for &t in &widths {
        eprintln!("bench-report: parallel x{t}...");
        let t0 = Instant::now();
        let par = run_specs_with_cache(&grid, t, &cache)?;
        let wall = t0.elapsed().as_secs_f64();
        anyhow::ensure!(
            seq == par,
            "{t}-thread runner diverged from sequential results — determinism violation"
        );
        measured.push((t, wall));
    }

    let batch_threads = measured.last().map(|&(t, _)| t).unwrap_or(1);
    eprintln!("bench-report: lockstep batched x{batch_threads}...");
    let t0 = Instant::now();
    let batched = crate::experiments::batched::run_specs_batched(&grid, batch_threads, &cache)?;
    let batched_wall_s = t0.elapsed().as_secs_f64();
    anyhow::ensure!(
        seq == batched,
        "batched executor diverged from sequential results — determinism violation"
    );
    // PR-6: the sparse pass. Timed with the tick skipper on, then
    // asserted bit-identical to untimed dense-tick twins — the bench
    // run itself proves the fast-forward path exact on this grid.
    let sparse = sparse_grid(&cfg);
    for spec in &sparse {
        spec.scenario.bank_variant(&cache); // warm, like the main grid
    }
    eprintln!("bench-report: sparse grid ({} runs, tick skipper on)...", sparse.len());
    let t0 = Instant::now();
    let skipped = run_specs_with_cache(&sparse, batch_threads, &cache)?;
    let sparse_wall_s = t0.elapsed().as_secs_f64();
    let dense: Vec<RunSpec> = sparse
        .iter()
        .map(|s| {
            let mut d = s.clone();
            d.scenario.dense_ticks = true;
            d
        })
        .collect();
    let dense = run_specs_with_cache(&dense, batch_threads, &cache)?;
    anyhow::ensure!(
        skipped == dense,
        "tick-skipped sparse runs diverged from dense-tick twins — fast-forward is not exact"
    );
    let sparse_ticks_executed: u64 = skipped.iter().map(|m| m.ticks_executed()).sum();
    let sparse_ticks_skipped: u64 = skipped.iter().map(|m| m.ticks_skipped).sum();
    anyhow::ensure!(
        sparse_ticks_skipped > 0,
        "sparse grid executed every tick — the skipper never engaged"
    );

    // PR-8: the streaming pass — one million tasks, suites generated at
    // arrival instants, terminal shards retired. The residency ensures
    // below are the whole point: peak live shards must track the
    // arrival window (TTC / arrival interval = 60 steady-state live
    // workloads, 4x margin for footprint/drain transients), never the
    // 10k-workload task total.
    let stream_cell = super::parallel::stream_grid(&cfg, false)
        .pop()
        .expect("stream_grid always carries the 1M cell when smoke is off");
    let stream_tasks = stream_cell.n_tasks();
    stream_cell.scenario.bank_variant(&cache); // warm, like the other passes
    eprintln!(
        "bench-report: streaming pass ({stream_tasks} tasks, lazy suite + shard retirement)..."
    );
    let t0 = Instant::now();
    let streamed = stream_cell.execute_with_cache(&cache)?;
    let stream_wall_s = t0.elapsed().as_secs_f64();
    anyhow::ensure!(
        streamed.tasks_completed == stream_tasks,
        "streaming pass lost tasks: {} of {stream_tasks} completed",
        streamed.tasks_completed
    );
    anyhow::ensure!(
        streamed.peak_live_shards >= 1 && streamed.peak_live_shards <= 240,
        "streaming pass peak residency ({} live shards) is not bounded by the arrival window",
        streamed.peak_live_shards
    );
    let cache_stats = cache.stats();

    eprintln!("bench-report: task-DB microbench (arena vs legacy)...");
    let db_tasks = if smoke { 10_000 } else { 50_000 };
    let ticks = 200;
    // ops ≈ one insert + claim + complete per task, plus the tick queries
    let db_ops = 3 * db_tasks + 6 * ticks;
    let db_arena_ops_per_s = ops_per_s(|| drive_arena(db_tasks, ticks), db_ops);
    let db_legacy_ops_per_s = ops_per_s(|| drive_legacy(db_tasks, ticks), db_ops);

    let report = BenchReport {
        grid: if smoke { "cost-smoke" } else { "cost-default" },
        runs,
        tasks_total,
        seq_wall_s,
        widths: measured,
        batched_wall_s,
        sparse_runs: sparse.len(),
        sparse_wall_s,
        sparse_ticks_executed,
        sparse_ticks_skipped,
        stream_tasks,
        stream_wall_s,
        stream_peak_live_shards: streamed.peak_live_shards,
        stream_peak_arena_bytes: streamed.peak_arena_bytes,
        db_tasks,
        db_legacy_ops_per_s,
        db_arena_ops_per_s,
        cache_hits: cache_stats.hits,
        cold_builds: cache_stats.cold_builds,
    };
    let json = report.to_json();
    if let Some(dir) = std::path::Path::new(out_path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(out_path, &json)?;
    let curve = report
        .sweep_series()
        .iter()
        .map(|&(t, tps)| format!("{t}t:{tps:.0}"))
        .collect::<Vec<_>>()
        .join(" ");
    let summary = format!(
        "grid: {runs} runs / {tasks} tasks\n\
         sequential baseline: {sw:.2}s ({stp:.0} tasks/s)\n\
         parallel x{threads}:  {pw:.2}s ({ptp:.0} tasks/s, {spd:.2}x) | curve: {curve}\n\
         batched x{threads}:   {bw:.2}s ({btp:.0} tasks/s, lockstep)\n\
         sparse x{threads}:    {sparsew:.2}s ({ste} ticks executed / {sts} skipped, dense-twin verified)\n\
         stream x1:     {mw:.2}s ({mtk} tasks, {mtps:.0} tasks/s, peak {mpls} live shards / {mpab} arena bytes)\n\
         bank cache: {cold} cold builds / {hits} hits across all passes\n\
         task-DB: arena {da:.2e} ops/s vs legacy {dl:.2e} ops/s ({dspd:.2}x)\n\
         wrote {out_path}\n",
        tasks = report.tasks_total,
        sw = report.seq_wall_s,
        stp = report.seq_tasks_per_s(),
        pw = report.par_wall_s(),
        ptp = report.par_tasks_per_s(),
        spd = report.parallel_speedup(),
        bw = report.batched_wall_s,
        btp = report.batched_tasks_per_s(),
        sparsew = report.sparse_wall_s,
        ste = report.sparse_ticks_executed,
        sts = report.sparse_ticks_skipped,
        mw = report.stream_wall_s,
        mtk = report.stream_tasks,
        mtps = report.stream_tasks_per_s(),
        mpls = report.stream_peak_live_shards,
        mpab = report.stream_peak_arena_bytes,
        da = report.db_arena_ops_per_s,
        dl = report.db_legacy_ops_per_s,
        dspd = report.db_speedup(),
        threads = report.threads(),
        cold = report.cold_builds,
        hits = report.cache_hits,
    );
    println!("{summary}");
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drivers_agree_on_checksum() {
        // the two stores must do the same logical work, or the ops/s
        // comparison is meaningless
        assert_eq!(drive_arena(500, 10), drive_legacy(500, 10));
    }

    #[test]
    fn json_is_parseable_by_our_parser() {
        let r = BenchReport {
            grid: "cost-default",
            runs: 10,
            tasks_total: 12345,
            seq_wall_s: 10.0,
            widths: vec![(2, 5.0), (8, 2.0)],
            batched_wall_s: 2.5,
            sparse_runs: 3,
            sparse_wall_s: 0.5,
            sparse_ticks_executed: 400,
            sparse_ticks_skipped: 900,
            stream_tasks: 1_000_000,
            stream_wall_s: 20.0,
            stream_peak_live_shards: 72,
            stream_peak_arena_bytes: 1_200_000,
            db_tasks: 1000,
            db_legacy_ops_per_s: 1.0e6,
            db_arena_ops_per_s: 9.0e6,
            cache_hits: 19,
            cold_builds: 1,
        };
        assert_eq!(r.threads(), 8, "the max width is the headline thread count");
        let j = crate::util::json::parse(&r.to_json()).unwrap();
        assert_eq!(
            j.get("schema").unwrap().as_str(),
            Some("dithen-bench-report/v1")
        );
        assert_eq!(j.get("tasks_simulated_total").unwrap().as_usize(), Some(12345));
        assert_eq!(j.get("threads").unwrap().as_usize(), Some(8));
        // bank-cache observability (PR-4): hits/cold builds travel in
        // the report, and the throughput series carries *every*
        // measured thread count — a scaling curve, not two points
        let cache = j.get("cache").unwrap();
        assert_eq!(cache.get("cache_hits").unwrap().as_usize(), Some(19));
        assert_eq!(cache.get("cold_builds").unwrap().as_usize(), Some(1));
        let series = j.get("sweep_tasks_per_s").unwrap().as_arr().unwrap();
        assert_eq!(series.len(), 3);
        for (i, want_t) in [1usize, 2, 8].iter().enumerate() {
            assert_eq!(series[i].get("threads").unwrap().as_usize(), Some(*want_t));
        }
        assert!(
            (series[0].get("tasks_per_s").unwrap().as_f64().unwrap() - r.seq_tasks_per_s()).abs()
                < 0.1
        );
        assert!(
            (series[2].get("tasks_per_s").unwrap().as_f64().unwrap() - r.par_tasks_per_s()).abs()
                < 0.1
        );
        // the lockstep-batched throughput travels alongside the curve
        // (PR-5): the next PR's gate can read it from the artifact
        assert!(
            (j.get("batched_tasks_per_s").unwrap().as_f64().unwrap() - r.batched_tasks_per_s())
                .abs()
                < 0.1
        );
        // the sparse tick split travels in the report (PR-6): CI reads
        // ticks_skipped from the artifact to prove the skipper engaged
        let sparse = j.get("sparse").unwrap();
        assert_eq!(sparse.get("runs").unwrap().as_usize(), Some(3));
        assert_eq!(sparse.get("ticks_executed").unwrap().as_usize(), Some(400));
        assert_eq!(sparse.get("ticks_skipped").unwrap().as_usize(), Some(900));
        // the streaming residency receipts travel in the report (PR-8):
        // CI reads peak_live_shards from the artifact to prove the
        // million-task run stayed arrival-window-bounded
        let stream = j.get("stream").unwrap();
        assert_eq!(stream.get("tasks").unwrap().as_usize(), Some(1_000_000));
        assert_eq!(stream.get("peak_live_shards").unwrap().as_usize(), Some(72));
        assert_eq!(stream.get("peak_arena_bytes").unwrap().as_usize(), Some(1_200_000));
        assert!((stream.get("tasks_per_s").unwrap().as_f64().unwrap() - 50_000.0).abs() < 0.1);
        let cur = j.get("current").unwrap();
        // the DB workload size must travel with the ops/s numbers so
        // cross-report comparisons know what was measured
        assert_eq!(cur.get("db_tasks").unwrap().as_usize(), Some(1000));
        assert_eq!(
            j.get("baseline").unwrap().get("db_tasks").unwrap().as_usize(),
            Some(1000)
        );
        assert!(cur.get("speedup_vs_baseline").unwrap().as_f64().unwrap() > 4.9);
        assert!(cur.get("db_speedup_vs_legacy").unwrap().as_f64().unwrap() > 8.9);
    }

    #[test]
    fn single_thread_series_is_deduped() {
        let r = BenchReport {
            grid: "cost-smoke",
            runs: 4,
            tasks_total: 100,
            seq_wall_s: 1.0,
            widths: vec![],
            batched_wall_s: 1.0,
            sparse_runs: 0,
            sparse_wall_s: 0.0,
            sparse_ticks_executed: 0,
            sparse_ticks_skipped: 0,
            stream_tasks: 0,
            stream_wall_s: 0.0,
            stream_peak_live_shards: 0,
            stream_peak_arena_bytes: 0,
            db_tasks: 10,
            db_legacy_ops_per_s: 1.0,
            db_arena_ops_per_s: 1.0,
            cache_hits: 3,
            cold_builds: 1,
        };
        assert_eq!(r.threads(), 1);
        assert_eq!(r.sweep_series().len(), 1);
        let j = crate::util::json::parse(&r.to_json()).unwrap();
        assert_eq!(j.get("sweep_tasks_per_s").unwrap().as_arr().unwrap().len(), 1);
        // max width falls back to the sequential pass
        assert!(
            (j.get("current").unwrap().get("tasks_per_s").unwrap().as_f64().unwrap()
                - r.seq_tasks_per_s())
            .abs()
                < 0.1
        );
    }
}
