//! Paper-reproduction harness: one module per table/figure of §V and
//! Appendix A. Each `run_*` function executes the experiment on the
//! simulated substrates, prints the paper-shaped rows/series, writes CSVs
//! under `out/`, and returns a summary string recorded in EXPERIMENTS.md.
//!
//! Index (see DESIGN.md §5):
//!   fig5        workload-suite input sizes
//!   fig6, fig7  estimator convergence traces (FFMPEG, SIFT)
//!   table2      time-to-estimate + MAE per estimator / app class
//!   fig8, fig9  cumulative cost under the two fixed TTCs
//!   table3      overall cost + max instances
//!   table4      Lambda vs Dithen ImageMagick cost
//!   fig10,fig11 Split–Merge workload cost curves
//!   fig12,table5  spot-market traces and catalogue

pub mod ablation;
pub mod batched;
pub mod bench_check;
pub mod bench_report;
pub mod cost;
pub mod estimators;
pub mod fig5;
pub mod heterogeneous;
pub mod lambda;
pub mod market;
pub mod parallel;
pub mod splitmerge;

use crate::config::Config;

/// Where experiment CSVs land.
pub const OUT_DIR: &str = "out";

/// All experiment ids, in paper order (extensions last).
pub const ALL: &[&str] = &[
    "fig5", "fig6", "fig7", "table2", "fig8", "fig9", "table3", "table4", "fig10", "fig11",
    "fig12", "table5", "ablation", "heterogeneous",
];

/// Run one experiment by id.
pub fn run(id: &str, cfg: &Config) -> anyhow::Result<String> {
    match id {
        "fig5" => fig5::run(cfg),
        "fig6" => estimators::run_fig(cfg, crate::workload::App::Transcode, "fig6"),
        "fig7" => estimators::run_fig(cfg, crate::workload::App::SiftMatlab, "fig7"),
        "table2" => estimators::run_table2(cfg),
        "fig8" => cost::run_fig(cfg, cost::TTC_LONG_S, "fig8"),
        "fig9" => cost::run_fig(cfg, cost::TTC_SHORT_S, "fig9"),
        "table3" => cost::run_table3(cfg),
        "table4" => lambda::run(cfg),
        "fig10" => splitmerge::run_cnn(cfg),
        "fig11" => splitmerge::run_wordcount(cfg),
        "fig12" => market::run_fig12(cfg),
        "table5" => market::run_table5(cfg),
        "ablation" => ablation::run(cfg),
        "heterogeneous" => heterogeneous::run(cfg),
        other => anyhow::bail!("unknown experiment id '{other}' (use one of {ALL:?})"),
    }
}

/// Run every experiment; returns the concatenated reports.
pub fn run_all(cfg: &Config) -> anyhow::Result<String> {
    let mut out = String::new();
    for id in ALL {
        out.push_str(&format!("\n########## {id} ##########\n"));
        out.push_str(&run(id, cfg)?);
    }
    Ok(out)
}
