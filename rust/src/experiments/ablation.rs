//! Ablation: AIMD constant sensitivity (§IV's design discussion).
//!
//! Shorten et al.'s analysis (the paper's justification for α = 5,
//! β = 0.9): small β converges fast but releases CUs prematurely; β near
//! 1 is smooth but slow to shed cost. This sweep quantifies that
//! trade-off on the paper suite — cost, instance peak and TTC compliance
//! per (α, β) — plus a monitoring-interval column (the paper's other
//! free knob).

use crate::config::Config;
use crate::coordinator::PolicyKind;
use crate::platform::{run_experiment, RunOpts};
use crate::util::table::Table;
use crate::workload::paper_suite;

pub const ALPHAS: [f64; 3] = [2.0, 5.0, 10.0];
pub const BETAS: [f64; 3] = [0.5, 0.9, 0.99];

pub fn run(cfg: &Config) -> anyhow::Result<String> {
    let mut t = Table::new(vec![
        "alpha",
        "beta",
        "cost ($)",
        "max instances",
        "TTC compliance (%)",
    ]);
    let mut paper_cost = f64::NAN;
    for &alpha in &ALPHAS {
        for &beta in &BETAS {
            let mut c = cfg.clone();
            c.control.monitor_interval_s = 300;
            c.control.alpha = alpha;
            c.control.beta = beta;
            let m = run_experiment(c.clone(), paper_suite(c.seed), RunOpts {
                policy: PolicyKind::Aimd,
                fixed_ttc_s: Some(super::cost::TTC_LONG_S),
                horizon_s: 16 * 3600,
                ..Default::default()
            })?;
            if alpha == 5.0 && beta == 0.9 {
                paper_cost = m.total_cost;
            }
            t.row(vec![
                format!("{alpha}"),
                format!("{beta}"),
                format!("{:.3}", m.total_cost),
                format!("{}", m.max_instances),
                format!("{:.0}", 100.0 * m.ttc_compliance()),
            ]);
        }
    }
    let summary = format!(
        "paper setting (alpha=5, beta=0.9) cost: ${paper_cost:.3}; the sweep shows the\n\
         §IV trade-off: small beta sheds capacity fast (cheap, deadline risk),\n\
         beta→1 holds capacity (smooth, costlier), larger alpha overshoots spikes\n"
    );
    let out = format!("{}{}", t.render(), summary);
    println!("{out}");
    Ok(out)
}

#[cfg(test)]
mod tests {
    #[test]
    fn sweep_covers_paper_setting() {
        assert!(super::ALPHAS.contains(&5.0));
        assert!(super::BETAS.contains(&0.9));
    }
}
