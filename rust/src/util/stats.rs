//! Small statistics toolbox used across estimators, metrics and benches.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for len < 2.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile (nearest-rank, p in [0, 100]); NaN for empty input.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Mean absolute error between two equal-length series.
pub fn mae(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum::<f64>() / a.len() as f64
}

/// Ordinary least squares over (x, y): returns (slope, intercept).
/// Degenerate inputs (len < 2 or zero x-variance) return slope 0 through
/// the mean.
pub fn linear_regression(x: &[f64], y: &[f64]) -> (f64, f64) {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    if x.len() < 2 {
        return (0.0, mean(y));
    }
    let mx = mean(x);
    let my = mean(y);
    let sxx: f64 = x.iter().map(|v| (v - mx) * (v - mx)).sum();
    if sxx == 0.0 {
        return (0.0, my);
    }
    let sxy: f64 = x.iter().zip(y).map(|(a, b)| (a - mx) * (b - my)).sum();
    let slope = sxy / sxx;
    (slope, my - slope * mx * (n / n))
}

/// Extrapolate a least-squares line fitted to the last `window` points of
/// `y` (equally spaced at 1.0) `steps` steps beyond the final point.
/// This is the "LR" capacity controller of Gandhi / Krioukov et al.
pub fn lr_extrapolate(y: &[f64], window: usize, steps: f64) -> f64 {
    let tail = if y.len() > window { &y[y.len() - window..] } else { y };
    let xs: Vec<f64> = (0..tail.len()).map(|i| i as f64).collect();
    let (m, b) = linear_regression(&xs, tail);
    m * (tail.len() as f64 - 1.0 + steps) + b
}

/// Welford online mean/variance accumulator.
#[derive(Debug, Clone, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Online {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }
    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn std_basic() {
        assert!((std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
        assert_eq!(std(&[1.0]), 0.0);
    }

    #[test]
    fn percentile_basic() {
        let v = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 50.0), 3.0);
        assert_eq!(percentile(&v, 100.0), 5.0);
    }

    #[test]
    fn mae_basic() {
        assert_eq!(mae(&[1.0, 2.0], &[2.0, 4.0]), 1.5);
    }

    #[test]
    fn regression_recovers_line() {
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 * v + 1.0).collect();
        let (m, b) = linear_regression(&x, &y);
        assert!((m - 3.0).abs() < 1e-9 && (b - 1.0).abs() < 1e-9);
    }

    #[test]
    fn regression_degenerate() {
        let (m, b) = linear_regression(&[1.0], &[5.0]);
        assert_eq!((m, b), (0.0, 5.0));
        let (m, b) = linear_regression(&[2.0, 2.0], &[1.0, 3.0]);
        assert_eq!(m, 0.0);
        assert_eq!(b, 2.0);
    }

    #[test]
    fn lr_extrapolation_continues_trend() {
        let y: Vec<f64> = (0..6).map(|i| 2.0 * i as f64).collect(); // 0,2,..,10
        let next = lr_extrapolate(&y, 6, 1.0);
        assert!((next - 12.0).abs() < 1e-9);
    }

    #[test]
    fn online_matches_batch() {
        let xs = [1.0, 4.0, 2.0, 8.0, 5.0];
        let mut o = Online::default();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - mean(&xs)).abs() < 1e-12);
        assert!((o.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(o.count(), 5);
    }
}
