//! Tiny property-based testing helper (the vendor set has no proptest).
//!
//! `forall` runs a property over `n` random cases drawn from the crate's
//! deterministic RNG; on failure it reports the failing case index and the
//! seed so the case can be replayed exactly. Shrinking is intentionally
//! out of scope — failures print enough context to debug directly.

use super::rng::Rng;

/// Run `prop` over `n` random cases. `gen` builds a case from an RNG;
/// `prop` returns `Err(reason)` to fail. Panics with seed + case on error.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    n: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let root = Rng::new(seed);
    for case in 0..n {
        let mut rng = root.substream(case as u64);
        let input = gen(&mut rng);
        if let Err(why) = prop(&input) {
            panic!(
                "property '{name}' failed (seed={seed}, case={case}): {why}\ninput: {input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        forall("abs-nonneg", 1, 200, |r| r.gauss(0.0, 10.0), |x| {
            if x.abs() >= 0.0 { Ok(()) } else { Err("negative abs".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn reports_failures() {
        forall("always-fails", 2, 10, |r| r.f64(), |_| Err("nope".into()));
    }

    #[test]
    fn deterministic_cases() {
        let mut first: Vec<f64> = vec![];
        forall("collect", 3, 5, |r| r.f64(), |x| {
            first.push(*x);
            Ok(())
        });
        let mut second: Vec<f64> = vec![];
        forall("collect", 3, 5, |r| r.f64(), |x| {
            second.push(*x);
            Ok(())
        });
        assert_eq!(first, second);
    }
}
