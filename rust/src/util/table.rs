//! ASCII table / series printers for the paper-figure harness.
//!
//! Every `dithen repro <exp>` prints its rows through this module so the
//! output format is uniform and easy to diff against EXPERIMENTS.md.

/// Simple column-aligned table.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table { header: header.into_iter().map(Into::into).collect(), rows: vec![] }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = w[i].max(h.len());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let sep: String = w
            .iter()
            .map(|n| format!("+{}", "-".repeat(n + 2)))
            .collect::<String>()
            + "+";
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("| {:<width$} ", c, width = w[i]))
                .collect::<String>()
                + "|"
        };
        let mut out = String::new();
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format seconds as "XXm YYs" (paper's Table II convention).
pub fn fmt_mmss(secs: f64) -> String {
    let s = secs.round() as i64;
    format!("{:02}m {:02}s", s / 60, s % 60)
}

/// Format seconds as "H hr M min".
pub fn fmt_hm(secs: f64) -> String {
    let s = secs.round() as i64;
    format!("{} hr {:02} min", s / 3600, (s % 3600) / 60)
}

/// Render an (x, y) series as a coarse ASCII line chart: used by the
/// `repro figN` commands to show curve *shape* in the terminal, alongside
/// the CSV dump that carries the exact values.
pub fn ascii_chart(title: &str, series: &[(&str, &[(f64, f64)])], width: usize, height: usize) -> String {
    let mut out = format!("== {title} ==\n");
    let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    for (_, pts) in series {
        for &(x, y) in *pts {
            xmin = xmin.min(x);
            xmax = xmax.max(x);
            ymin = ymin.min(y);
            ymax = ymax.max(y);
        }
    }
    if !xmin.is_finite() || xmax <= xmin {
        return out + "(no data)\n";
    }
    if ymax <= ymin {
        ymax = ymin + 1.0;
    }
    let mut grid = vec![vec![b' '; width]; height];
    let marks: &[u8] = b"*o+x#@%&";
    for (si, (_, pts)) in series.iter().enumerate() {
        for &(x, y) in *pts {
            let cx = ((x - xmin) / (xmax - xmin) * (width as f64 - 1.0)).round() as usize;
            let cy = ((y - ymin) / (ymax - ymin) * (height as f64 - 1.0)).round() as usize;
            let row = height - 1 - cy.min(height - 1);
            grid[row][cx.min(width - 1)] = marks[si % marks.len()];
        }
    }
    out.push_str(&format!("y: [{ymin:.4}, {ymax:.4}]\n"));
    for row in grid {
        out.push('|');
        out.push_str(std::str::from_utf8(&row).unwrap());
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out.push_str(&format!("x: [{xmin:.1}, {xmax:.1}]   "));
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("{}={} ", marks[si % marks.len()] as char, name));
    }
    out.push('\n');
    out
}

/// Write series to a CSV file (one x column, one column per series).
pub fn write_csv(
    path: &str,
    xlabel: &str,
    series: &[(&str, &[(f64, f64)])],
) -> std::io::Result<()> {
    use std::io::Write;
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    // union of x values, sorted
    let mut xs: Vec<f64> = series.iter().flat_map(|(_, p)| p.iter().map(|q| q.0)).collect();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs.dedup();
    write!(f, "{xlabel}")?;
    for (name, _) in series {
        write!(f, ",{name}")?;
    }
    writeln!(f)?;
    for x in xs {
        write!(f, "{x}")?;
        for (_, pts) in series {
            // last point at or before x (step interpolation)
            let v = pts
                .iter()
                .take_while(|p| p.0 <= x)
                .last()
                .map(|p| p.1);
            match v {
                Some(v) => write!(f, ",{v}")?,
                None => write!(f, ",")?,
            }
        }
        writeln!(f)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(vec!["name", "value"]);
        t.row(vec!["a", "1"]).row(vec!["long-name", "22"]);
        let out = t.render();
        assert!(out.contains("| name      | value |"));
        assert!(out.contains("| long-name | 22    |"));
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_mmss(825.0), "13m 45s");
        assert_eq!(fmt_hm(7620.0), "2 hr 07 min");
    }

    #[test]
    fn chart_handles_empty_and_flat() {
        let empty: &[(f64, f64)] = &[];
        let out = ascii_chart("t", &[("s", empty)], 10, 4);
        assert!(out.contains("no data"));
        let flat = [(0.0, 1.0), (1.0, 1.0)];
        let out = ascii_chart("t", &[("s", &flat)], 10, 4);
        assert!(out.contains('*'));
    }

    #[test]
    fn csv_roundtrip() {
        let a = [(0.0, 1.0), (2.0, 3.0)];
        let path = "/tmp/dithen_test_csv.csv";
        write_csv(path, "t", &[("a", &a)]).unwrap();
        let body = std::fs::read_to_string(path).unwrap();
        assert!(body.starts_with("t,a\n"));
        assert!(body.contains("2,3"));
    }
}
