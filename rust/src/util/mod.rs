//! Self-contained utility substrates (no external deps beyond std).
//!
//! The offline vendor set ships neither rand, serde_json, clap nor
//! criterion, so the pieces this crate needs — deterministic RNG, small
//! statistics, a JSON subset parser, table/chart printers and a property-
//! test helper — are implemented here and tested in place.

pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;
