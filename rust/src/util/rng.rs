//! Deterministic PRNG for the simulation substrate.
//!
//! The whole platform must be reproducible from a single seed (experiment
//! reruns, CI, and the paper-figure harness all depend on it), so we use a
//! small, fully-owned xoshiro256++ implementation instead of an external
//! crate. Streams are derived with SplitMix64 so substreams (per task, per
//! instance, per workload) are independent of iteration order.

/// SplitMix64: used for seeding / stream derivation.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ deterministic PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed the generator; any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent substream, e.g. per task id. Deterministic in
    /// (parent seed-state, stream id) and independent of call order.
    pub fn substream(&self, id: u64) -> Rng {
        let mut sm = self.s[0] ^ id.wrapping_mul(0xA24B_AED4_963E_E407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [lo, hi] (inclusive).
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo + 1) as u64;
        lo + (self.next_u64() % span) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean / std.
    pub fn gauss(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal such that the *mean* of the distribution is `mean` and the
    /// coefficient of variation is `cv` (std/mean of the lognormal itself).
    /// This is the task-duration model: data-dependent execution times are
    /// right-skewed with occasional heavy items.
    pub fn lognormal_mean_cv(&mut self, mean: f64, cv: f64) -> f64 {
        if mean <= 0.0 {
            return 0.0;
        }
        let sigma2 = (1.0 + cv * cv).ln();
        let mu = mean.ln() - 0.5 * sigma2;
        (mu + sigma2.sqrt() * self.normal()).exp()
    }

    /// Exponential with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = (self.next_u64() % (i as u64 + 1)) as usize;
            v.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[(self.next_u64() % v.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_runs() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn substreams_independent_of_order() {
        let root = Rng::new(7);
        let mut s1a = root.substream(10);
        let _ = root.substream(11);
        let mut s1b = root.substream(10);
        assert_eq!(s1a.next_u64(), s1b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_respects_bounds() {
        let mut r = Rng::new(4);
        for _ in 0..10_000 {
            let x = r.uniform(5.0, 9.0);
            assert!((5.0..9.0).contains(&x));
        }
    }

    #[test]
    fn int_inclusive_bounds() {
        let mut r = Rng::new(5);
        let (mut saw_lo, mut saw_hi) = (false, false);
        for _ in 0..10_000 {
            let x = r.int(1, 6);
            assert!((1..=6).contains(&x));
            saw_lo |= x == 1;
            saw_hi |= x == 6;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn lognormal_mean_matches() {
        let mut r = Rng::new(8);
        let n = 200_000;
        let m: f64 = (0..n).map(|_| r.lognormal_mean_cv(10.0, 0.5)).sum::<f64>() / n as f64;
        assert!((m - 10.0).abs() < 0.15, "mean={m}");
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.lognormal_mean_cv(3.0, 1.5) > 0.0);
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(10);
        let n = 100_000;
        let m: f64 = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((m - 4.0).abs() < 0.1, "mean={m}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
