//! Minimal JSON parser — just enough for `artifacts/manifest.json`.
//!
//! The vendor set has no serde_json, and the manifest is the only JSON we
//! consume, so a ~150-line recursive-descent parser keeps the runtime
//! self-contained.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

pub fn parse(s: &str) -> Result<Json, ParseError> {
    let mut p = Parser { b: s.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.i, msg: msg.to_string() }
    }
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i]).unwrap());
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .unwrap()
            .parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_doc() {
        let doc = r#"{
          "format": "hlo-text",
          "variants": [{"w": 64, "k": 4, "file": "m.hlo.txt"}],
          "outputs": ["b_hat", "pi"]
        }"#;
        let j = parse(doc).unwrap();
        assert_eq!(j.get("format").unwrap().as_str(), Some("hlo-text"));
        let v = j.get("variants").unwrap().as_arr().unwrap();
        assert_eq!(v[0].get("w").unwrap().as_usize(), Some(64));
        assert_eq!(v[0].get("file").unwrap().as_str(), Some("m.hlo.txt"));
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested_arrays() {
        let j = parse("[1, [2, 3], []]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[1].as_arr().unwrap()[1].as_f64(), Some(3.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("hello").is_err());
        assert!(parse("{} extra").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }
}
